"""Mixture-of-Experts with BULK-STEAL token rebalancing.

This is the paper's technique applied inside the model: after top-k
routing, each expert is a "worker" whose queue is its assigned token
batch.  Experts past ``capacity`` would normally drop their overflow
(GShard).  Here a *virtual master* — one deterministic, replicated pass,
exactly like ``core.master`` — bulk-steals the overflow suffix and
reassigns it to the experts with slack:

  1. routing = bulk push: positions within each expert come from one
     vectorized cumsum (constant per-token cost — the paper's flat-latency
     bulk push).
  2. overflow detection = the ``_queue_limit_``/capacity guard.
  3. reassignment = proportional bulk steal: the k-th overflow token goes
     to the k-th unit of cross-expert slack (computed by one searchsorted
     over the cumulative-slack vector — a single "cut" per expert, the
     linearization-point analogue).

The result is *dropless* MoE with a deterministic O(T log T) plan and no
per-token synchronization.  ``moe_bulk_steal=False`` gives the GShard
drop baseline for ablations (paper-faithful "no steal" comparison).

Expert compute is grouped matmuls on (E, C, D) buffers: EP-sharded over
the TP axis when E % tp == 0 (qwen3: 128 experts), else capacity-sharded
with TP inside each expert (mixtral: 8 experts on tp=16).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ShardPlan, dense_init, shard, pscan

Pytree = Any

__all__ = ["moe_init", "moe_apply", "route_with_bulk_steal"]


def moe_init(key, L: int, d_model: int, n_experts: int, d_ff_e: int, dtype) -> Pytree:
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (L, d_model, n_experts), dtype),
        "w_gate": dense_init(ks[1], (L, n_experts, d_model, d_ff_e), dtype),
        "w_up": dense_init(ks[2], (L, n_experts, d_model, d_ff_e), dtype),
        "w_down": dense_init(ks[3], (L, n_experts, d_ff_e, d_model), dtype),
    }


# ---------------------------------------------------------------------------
# routing with bulk-steal rebalancing
# ---------------------------------------------------------------------------


def route_with_bulk_steal(
    probs: jnp.ndarray,      # (T, E) router softmax
    top_k: int,
    capacity: int,
    bulk_steal: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compute (expert, slot, weight, valid) for each of T*top_k assignments.

    Returns flat arrays of shape (T*top_k,):
      expert: expert id per assignment (possibly re-routed by the steal)
      slot:   position within the expert's capacity buffer
      weight: combine weight (router prob, renormalized per token)
      valid:  assignment lands in a real slot (always true for stolen
              tokens when total slack suffices; false only when the whole
              system is over capacity)
    """
    T, E = probs.shape
    w, experts = jax.lax.top_k(probs, top_k)              # (T, k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)

    flat_e = experts.reshape(-1)                          # (A,) A = T*k
    flat_w = w.reshape(-1)
    A = flat_e.shape[0]

    # --- bulk push: slot = rank of this assignment within its expert -------
    # Sort-based ranking: O(A log A) and O(A) memory (a (A, E) one-hot
    # cumsum would replicate multi-GB intermediates at the assigned scale).
    order = jnp.argsort(flat_e, stable=True)              # (A,)
    inv = jnp.zeros((A,), jnp.int32).at[order].set(
        jnp.arange(A, dtype=jnp.int32))
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=jnp.int32),
                             side="left").astype(jnp.int32)
    end = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=jnp.int32),
                           side="right").astype(jnp.int32)
    slot = inv - start[flat_e]                            # rank within expert
    load = end - start                                    # (E,) expert loads

    overflow = slot >= capacity
    if not bulk_steal:
        return flat_e, jnp.minimum(slot, capacity - 1), flat_w, ~overflow

    # --- proportional bulk steal of the overflow suffix ---------------------
    # Slack per expert and its cumulative vector: one searchsorted maps the
    # j-th overflow assignment to the expert owning the j-th slack unit.
    slack = jnp.maximum(capacity - load, 0)               # (E,)
    cum_slack = jnp.cumsum(slack)                         # (E,)
    total_slack = cum_slack[-1]

    # Rank the overflow assignments (stable order = routing order).
    ovf_rank = jnp.cumsum(overflow.astype(jnp.int32)) - overflow.astype(jnp.int32)
    thief = jnp.searchsorted(cum_slack, ovf_rank, side="right").astype(jnp.int32)
    thief = jnp.minimum(thief, E - 1)
    # Slot within the thief = base load + index within that thief's block.
    prev_cum = jnp.where(thief > 0, cum_slack[jnp.maximum(thief - 1, 0)], 0)
    thief_slot = load[thief] + (ovf_rank - prev_cum)

    stolen_ok = overflow & (ovf_rank < total_slack)
    new_e = jnp.where(stolen_ok, thief, flat_e)
    new_slot = jnp.where(stolen_ok, thief_slot, slot)
    # Stolen tokens keep their router weight for the ORIGINAL expert: the
    # thief computes on their behalf (the master moved the task, not the
    # objective) — mirrors redistributed solver nodes keeping their bounds.
    valid = (~overflow) | stolen_ok
    new_slot = jnp.clip(new_slot, 0, capacity - 1)
    return new_e, new_slot, flat_w, valid


# Token-chunk size for the dispatch pipeline: the (E, C, D) buffers and
# routing tensors scale with the chunk, not the full 1M-token batch, so
# per-device transients stay ~100s of MB at the assigned shapes.
MOE_CHUNK_TOKENS = 65_536


def _moe_chunk(p, xt, *, top_k, n_experts, capacity_factor, sh,
               compute_dtype, bulk_steal, ep):
    """MoE for one (Tc, D) token chunk."""
    Tc, D = xt.shape
    E = n_experts
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(compute_dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    capacity = int(max(Tc * top_k / E * capacity_factor, top_k))
    capacity = -(-capacity // 8) * 8  # round up to 8 for clean layouts
    expert, slot, weight, valid = route_with_bulk_steal(
        probs, top_k, capacity, bulk_steal=bulk_steal)

    tok = jnp.repeat(jnp.arange(Tc, dtype=jnp.int32), top_k)

    # Dispatch: scatter token vectors into the (E, C, D) expert buffers.
    flat_idx = jnp.where(valid, expert * capacity + slot, E * capacity)
    buf = jnp.zeros((E * capacity, D), compute_dtype)
    buf = buf.at[flat_idx].set(xt[tok], mode="drop")
    buf = buf.reshape(E, capacity, D)
    buf = shard(buf, sh.tp if ep else None, None if ep else sh.tp, None)

    # Expert compute: grouped SwiGLU matmuls (EP over experts when the
    # expert count divides the TP axis, else TP inside each expert).
    wg = p["w_gate"].astype(compute_dtype)
    wu = p["w_up"].astype(compute_dtype)
    wd = p["w_down"].astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
    h = shard(h, sh.tp if ep else None, None if ep else sh.tp, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E * capacity, D)

    # Combine: gather back and weight.
    gathered = out_buf[jnp.minimum(flat_idx, E * capacity - 1)]
    gathered = gathered * (weight * valid.astype(jnp.float32)).astype(compute_dtype)[:, None]
    out = jnp.zeros((Tc, D), compute_dtype).at[tok].add(gathered)
    return out


def moe_apply(p: Pytree, x: jnp.ndarray, *, top_k: int, n_experts: int,
              capacity_factor: float, sh: ShardPlan, compute_dtype,
              bulk_steal: bool = True, impl: str = "gspmd") -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D). p leaves are per-layer (no L dim).

    Tokens are processed in MOE_CHUNK_TOKENS chunks via lax.scan — a
    dispatch PIPELINE that bounds routing/buffer transients (the steal
    rebalancing scope is the chunk).  One chunk == one bulk push+steal
    round of the paper's model.

    impl="gspmd": auto-partitioned dispatch (baseline — GSPMD turns the
    token->expert scatter into large all-gathers).
    impl="ep_shardmap": explicit expert parallelism (see
    moe_apply_ep_shardmap) — beyond-paper §Perf optimization.
    """
    if impl == "ep_shardmap":
        out = moe_apply_ep_shardmap(
            p, x, top_k=top_k, n_experts=n_experts,
            capacity_factor=capacity_factor, sh=sh,
            compute_dtype=compute_dtype, bulk_steal=bulk_steal)
        if out is not None:
            return out
        # fall through to gspmd when no mesh / experts don't divide tp

    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D).astype(compute_dtype)
    tp = _tp_size(sh)
    ep = (n_experts % tp == 0) if tp else True

    kw = dict(top_k=top_k, n_experts=n_experts,
              capacity_factor=capacity_factor, sh=sh,
              compute_dtype=compute_dtype, bulk_steal=bulk_steal, ep=ep)

    if T <= MOE_CHUNK_TOKENS:
        out = _moe_chunk(p, xt, **kw)
        return shard(out.reshape(B, S, D), sh.dp, None, None)

    nc = -(-T // MOE_CHUNK_TOKENS)
    while T % nc:
        nc += 1
    xc = xt.reshape(nc, T // nc, D)

    def step(_, xchunk):
        return None, _moe_chunk(p, xchunk, **kw)

    _, out = pscan(step, None, xc)
    out = out.reshape(T, D)
    return shard(out.reshape(B, S, D), sh.dp, None, None)


# ---------------------------------------------------------------------------
# Optimized expert-parallel dispatch (beyond-paper, §Perf)
# ---------------------------------------------------------------------------


def moe_apply_ep_shardmap(p: Pytree, x: jnp.ndarray, *, top_k: int,
                          n_experts: int, capacity_factor: float,
                          sh: ShardPlan, compute_dtype,
                          bulk_steal: bool = True):
    """Explicit EP via shard_map: activations are REPLICATED over the TP
    axis in this framework's layout, so each TP rank can (a) run the
    identical routing plan, (b) LOCALLY gather the tokens assigned to its
    own E/tp experts (zero dispatch collectives — the GSPMD baseline
    all-gathers hundreds of GB here), (c) compute its grouped matmuls,
    and (d) combine with ONE psum over tp.  Per-device wire bytes drop
    from O(T*D) gathers to one (T_loc, D) all-reduce per chunk.

    Returns None when unavailable (no mesh, or E % tp != 0).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.models.layers import _active_mesh

    mesh = _active_mesh()
    if mesh is None or sh.tp not in mesh.axis_names:
        return None
    tp_size = mesh.shape[sh.tp]
    if n_experts % tp_size != 0:
        return None
    dp_axes = tuple(a for a in
                    (sh.dp if isinstance(sh.dp, (tuple, list)) else (sh.dp,))
                    if a in mesh.axis_names)
    B, S, D = x.shape
    Eo = n_experts // tp_size  # experts per rank

    def local_fn(pl, xl):
        rank = jax.lax.axis_index(sh.tp)
        Bl, Sl, _ = xl.shape
        Tl = Bl * Sl
        xt = xl.reshape(Tl, D).astype(compute_dtype)

        def chunk(xt_c):
            Tc = xt_c.shape[0]
            logits = jnp.einsum("td,de->te", xt_c,
                                pl["router"].astype(compute_dtype))
            probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
            capacity = int(max(Tc * top_k / n_experts * capacity_factor,
                               top_k))
            capacity = -(-capacity // 8) * 8
            expert, slot, weight, valid = route_with_bulk_steal(
                probs, top_k, capacity, bulk_steal=bulk_steal)
            tok = jnp.repeat(jnp.arange(Tc, dtype=jnp.int32), top_k)
            # keep only assignments owned by this rank's experts
            mine = valid & (expert // Eo == rank)
            local_e = expert % Eo
            flat_idx = jnp.where(mine, local_e * capacity + slot,
                                 Eo * capacity)
            buf = jnp.zeros((Eo * capacity, D), compute_dtype)
            buf = buf.at[flat_idx].set(xt_c[tok], mode="drop")
            buf = buf.reshape(Eo, capacity, D)
            wg = pl["w_gate"].astype(compute_dtype)
            wu = pl["w_up"].astype(compute_dtype)
            wd = pl["w_down"].astype(compute_dtype)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
            h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
            ob = jnp.einsum("ecf,efd->ecd", h, wd).reshape(Eo * capacity, D)
            g = ob[jnp.minimum(flat_idx, Eo * capacity - 1)]
            g = g * (weight * mine.astype(jnp.float32)
                     ).astype(compute_dtype)[:, None]
            out = jnp.zeros((Tc, D), compute_dtype).at[tok].add(g)
            # ONE combine collective: sum each rank's expert contributions
            return jax.lax.psum(out, sh.tp)

        if Tl <= MOE_CHUNK_TOKENS:
            out = chunk(xt)
        else:
            nc = -(-Tl // MOE_CHUNK_TOKENS)
            while Tl % nc:
                nc += 1
            _, out = pscan(lambda c, xc: (None, chunk(xc)), None,
                           xt.reshape(nc, Tl // nc, D))
            out = out.reshape(Tl, D)
        return out.reshape(Bl, Sl, D)

    pspec = {
        "router": P(None, None),
        "w_gate": P(sh.tp, None, None),
        "w_up": P(sh.tp, None, None),
        "w_down": P(sh.tp, None, None),
    }
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(pspec, P(dp_axes or None, None, None)),
                   out_specs=P(dp_axes or None, None, None),
                   check_rep=False)
    return fn(p, x.astype(compute_dtype))


def _tp_size(sh: ShardPlan) -> int:
    from repro.models.layers import _active_mesh

    m = _active_mesh()
    if m is None or sh.tp not in m.axis_names:
        return 0
    return m.shape[sh.tp]
