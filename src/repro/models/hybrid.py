"""Hybrid Mamba2 + shared-attention model (zamba2-7b).

Layer plan for ``n_layers=81, attn_every=6``: 13 groups of 6 mamba blocks,
each group followed by ONE application of a SHARED attention+MLP block
(one parameter set reused 13 times — zamba2's signature trick), plus a
tail of 81 - 78 = 3 mamba blocks.  Grouping (instead of a per-layer cond
inside one scan) keeps HLO FLOP counts honest: attention ops appear once
per group, not once per layer.

The shared block's KV caches are per-APPLICATION (13 of them) even though
the weights are shared.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.attention import AttnConfig, attn_init, attention, decode_attention
from repro.models.layers import (
    pscan,
    ShardPlan,
    chunked_ce_loss,
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    rms_norm,
    shard,
)
from repro.models.ssm import (
    SSMCache,
    SSMConfig,
    mamba_block,
    mamba_decode_step,
    ssm_init,
)

Pytree = Any

__all__ = ["HybridLM", "SSMLM"]

_SEQ_SHARD_MIN = 8192


class HybridLM:
    def __init__(self, cfg: ModelConfig, sh: Optional[ShardPlan] = None):
        self.cfg = cfg
        self.sh = sh or ShardPlan()
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.cdtype = jnp.dtype(cfg.compute_dtype)
        self.n_groups = cfg.n_layers // cfg.attn_every
        self.tail = cfg.n_layers - self.n_groups * cfg.attn_every
        self.scfg = SSMConfig(
            d_model=cfg.d_model, d_inner=cfg.d_inner, n_heads=cfg.n_ssm_heads,
            head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
            conv_dim=cfg.ssm_conv_dim, chunk=cfg.ssm_chunk)
        self.acfg = AttnConfig(
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, rope_fraction=cfg.rope_fraction,
            window=None, softcap=None, qk_norm=False, causal=True)

    # ------------------------------------------------------------------ init

    def init(self, key) -> Pytree:
        cfg = self.cfg
        NG, AE, D = self.n_groups, cfg.attn_every, cfg.d_model
        ks = jax.random.split(key, 8)
        grouped = {
            "ln": jnp.ones((NG, AE, D), self.dtype),
            "ssm": _stack2(ssm_init(ks[0], NG * AE, self.scfg, self.dtype),
                           NG, AE),
        }
        shared = {
            "ln1": jnp.ones((D,), self.dtype),
            "ln2": jnp.ones((D,), self.dtype),
            "attn": _squeeze(attn_init(ks[1], 1, D, self.acfg, self.dtype)),
            "mlp": _squeeze(mlp_init(ks[2], 1, D, cfg.d_ff, self.dtype)),
        }
        params = {
            "embed": embed_init(ks[3], cfg.padded_vocab, D, self.dtype),
            "grouped": grouped,
            "shared": shared,
            "final_norm": jnp.ones((D,), self.dtype),
        }
        if self.tail:
            params["tail"] = {
                "ln": jnp.ones((self.tail, D), self.dtype),
                "ssm": ssm_init(ks[4], self.tail, self.scfg, self.dtype),
            }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[5], (D, cfg.padded_vocab), self.dtype)
        return params

    def param_specs(self) -> Pytree:
        cfg, sh = self.cfg, self.sh
        tp, fs = sh.tp, sh.fsdp

        def ssm_specs(lead):
            n = (None,) * lead
            return {
                "w_z": P(*n, fs, tp), "w_x": P(*n, fs, tp),
                "w_B": P(*n, fs, None), "w_C": P(*n, fs, None),
                "w_dt": P(*n, fs, tp),
                "conv_x": P(*n, None, tp), "conv_B": P(*n, None, None),
                "conv_C": P(*n, None, None),
                "A_log": P(*n, tp), "D": P(*n, tp), "dt_bias": P(*n, tp),
                "out_proj": P(*n, tp, fs), "gate_norm": P(*n, tp),
            }

        specs = {
            "embed": P(tp, fs),
            "grouped": {"ln": P(None, None, None), "ssm": ssm_specs(2)},
            "shared": {
                "ln1": P(None), "ln2": P(None),
                "attn": {"wq": P(fs, tp), "wk": P(fs, tp),
                         "wv": P(fs, tp), "wo": P(tp, fs)},
                "mlp": {"w_gate": P(fs, tp), "w_up": P(fs, tp),
                        "w_down": P(tp, fs)},
            },
            "final_norm": P(None),
        }
        if self.tail:
            specs["tail"] = {"ln": P(None, None), "ssm": ssm_specs(1)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = P(fs, tp)
        return specs

    # ------------------------------------------------------------- forward

    def _shared_block(self, params, x, positions):
        cfg, sh = self.cfg, self.sh
        s = params["shared"]
        h = rms_norm(x, s["ln1"], cfg.norm_eps)
        x = x + attention(s["attn"], h, self.acfg, sh, self.cdtype,
                          positions=positions)
        h = rms_norm(x, s["ln2"], cfg.norm_eps)
        x = x + mlp_apply(s["mlp"], h, sh, self.cdtype)
        return shard(x, sh.dp, None, sh.tp)

    def forward(self, params, tokens) -> jnp.ndarray:
        cfg, sh = self.cfg, self.sh
        x = params["embed"][tokens].astype(self.cdtype)
        x = shard(x, sh.dp, None, sh.tp)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)

        def group_fn(x, pg):
            def mamba_fn(x, pl):
                h = rms_norm(x, pl["ln"], cfg.norm_eps)
                x = x + mamba_block(pl["ssm"], h, self.scfg, sh, self.cdtype)
                return shard(x, sh.dp, None, sh.tp), None

            x, _ = pscan(mamba_fn, x, {"ln": pg["ln"], "ssm": pg["ssm"]})
            x = self._shared_block(params, x, positions)
            return x, None

        body = group_fn
        if cfg.remat:
            body = jax.checkpoint(group_fn,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = pscan(body, x, params["grouped"])

        if self.tail:
            def tail_fn(x, pl):
                h = rms_norm(x, pl["ln"], cfg.norm_eps)
                x = x + mamba_block(pl["ssm"], h, self.scfg, sh, self.cdtype)
                return shard(x, sh.dp, None, sh.tp), None
            tb = tail_fn
            if cfg.remat:
                tb = jax.checkpoint(tail_fn,
                                    policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = pscan(tb, x, params["tail"])
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def _head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def loss_fn(self, params, batch) -> jnp.ndarray:
        hidden = self.forward(params, batch["tokens"])
        return chunked_ce_loss(hidden, self._head(params).astype(self.cdtype),
                               batch["labels"], batch.get("loss_mask"),
                               self.sh, remat=self.cfg.remat)

    # --------------------------------------------------------------- serving

    def make_cache(self, batch: int, seq_len: int) -> Pytree:
        cfg = self.cfg
        NG, AE = self.n_groups, cfg.attn_every
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        nh, hd, ns = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

        def ssm_cache(n):
            return {
                "conv_buf": jnp.zeros((n, batch, cfg.ssm_conv_dim - 1, conv_ch),
                                      self.cdtype),
                "state": jnp.zeros((n, batch, nh, hd, ns), jnp.float32),
            }

        cache = {
            "pos": jnp.zeros((), jnp.int32),
            "grouped_ssm": {
                "conv_buf": jnp.zeros((NG, AE, batch, cfg.ssm_conv_dim - 1, conv_ch),
                                      self.cdtype),
                "state": jnp.zeros((NG, AE, batch, nh, hd, ns), jnp.float32),
            },
            "shared_attn": {
                "k": jnp.zeros((NG, batch, seq_len, cfg.n_kv_heads, cfg.hd),
                               self.cdtype),
                "v": jnp.zeros((NG, batch, seq_len, cfg.n_kv_heads, cfg.hd),
                               self.cdtype),
            },
        }
        if self.tail:
            cache["tail_ssm"] = ssm_cache(self.tail)
        return cache

    def cache_specs(self, seq_len: int, batch: int = 0) -> Pytree:
        sh = self.sh
        tiny = 0 < batch < 16
        dp = None if tiny else sh.dp
        if tiny:
            kv = P(None, None, tuple(sh.dp) + (sh.tp,), None, None)
        elif seq_len >= _SEQ_SHARD_MIN:
            kv = P(None, sh.dp, sh.tp, None, None)
        else:
            kv = P(None, sh.dp, None, None, None)
        specs = {
            "pos": P(),
            "grouped_ssm": {"conv_buf": P(None, None, dp, None, None),
                            "state": P(None, None, dp, sh.tp, None, None)},
            "shared_attn": {"k": kv, "v": kv},
        }
        if self.tail:
            specs["tail_ssm"] = {"conv_buf": P(None, dp, None, None),
                                 "state": P(None, dp, sh.tp, None, None)}
        return specs

    def grow_cache(self, cache: Pytree, target_len: int) -> Pytree:
        """Shared-attn cache is linear: zero-pad; SSM state is O(1)."""
        sa = cache["shared_attn"]
        C = sa["k"].shape[2]
        if C >= target_len:
            return cache
        padw = [(0, 0)] * sa["k"].ndim
        padw[2] = (0, target_len - C)
        out = dict(cache)
        out["shared_attn"] = {"k": jnp.pad(sa["k"], padw),
                              "v": jnp.pad(sa["v"], padw)}
        return out

    def prefill(self, params, tokens) -> Tuple[jnp.ndarray, Pytree]:
        """Prefill via teacher-forced forward; SSM states rebuilt by a
        final-state pass.  For simplicity the prefill recomputes the scan
        with state capture (same FLOPs as forward)."""
        cfg, sh = self.cfg, self.sh
        x = params["embed"][tokens].astype(self.cdtype)
        x = shard(x, sh.dp, None, sh.tp)
        B, S, _ = x.shape
        positions = jnp.arange(S, dtype=jnp.int32)
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state

        def capture_mamba(x, pl):
            from repro.models.ssm import _causal_conv
            import jax.nn as jnn
            h = rms_norm(x, pl["ln"], cfg.norm_eps)
            # replicate mamba_block but capture conv tail + final state
            cd = self.cdtype
            hc = h.astype(cd)
            z = jnp.einsum("bsd,dk->bsk", hc, pl["ssm"]["w_z"].astype(cd))
            xs = jnp.einsum("bsd,dk->bsk", hc, pl["ssm"]["w_x"].astype(cd))
            Bm = jnp.einsum("bsd,dn->bsn", hc, pl["ssm"]["w_B"].astype(cd))
            Cm = jnp.einsum("bsd,dn->bsn", hc, pl["ssm"]["w_C"].astype(cd))
            dt = jnp.einsum("bsd,dh->bsh", hc, pl["ssm"]["w_dt"].astype(cd))
            conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
            tail = conv_in[:, S - (cfg.ssm_conv_dim - 1):, :]
            xs = jnn.silu(_causal_conv(xs, pl["ssm"]["conv_x"].astype(cd)))
            Bm = jnn.silu(_causal_conv(Bm, pl["ssm"]["conv_B"].astype(cd)))
            Cm = jnn.silu(_causal_conv(Cm, pl["ssm"]["conv_C"].astype(cd)))
            dt = jnn.softplus(dt.astype(jnp.float32)
                              + pl["ssm"]["dt_bias"][None, None, :])
            A = -jnp.exp(pl["ssm"]["A_log"])
            from repro.models.ssm import ssd_chunked
            xs4 = xs.reshape(B, S, self.scfg.n_heads, self.scfg.head_dim)
            y, fin = ssd_chunked(xs4, dt, A, Bm, Cm, pl["ssm"]["D"],
                                 self.scfg.chunk, sh=sh)
            y = y.reshape(B, S, cfg.d_inner)
            y = rms_norm(y * jnn.silu(z.astype(jnp.float32)).astype(y.dtype),
                         pl["ssm"]["gate_norm"])
            out = jnp.einsum("bsk,kd->bsd", y.astype(cd),
                             pl["ssm"]["out_proj"].astype(cd))
            x = x + shard(out, sh.dp, None, None)
            return shard(x, sh.dp, None, sh.tp), (tail.astype(self.cdtype), fin)

        def group_fn(x, pg):
            x, caches = pscan(capture_mamba, x,
                                     {"ln": pg["ln"], "ssm": pg["ssm"]})
            h = rms_norm(x, params["shared"]["ln1"], cfg.norm_eps)
            a, (k, v) = attention(params["shared"]["attn"], h, self.acfg, sh,
                                  self.cdtype, positions=positions,
                                  return_kv=True)
            x = x + a
            h = rms_norm(x, params["shared"]["ln2"], cfg.norm_eps)
            x = x + mlp_apply(params["shared"]["mlp"], h, sh, self.cdtype)
            x = shard(x, sh.dp, None, sh.tp)
            return x, (caches, (k.astype(self.cdtype), v.astype(self.cdtype)))

        x, (g_caches, attn_kv) = pscan(group_fn, x, params["grouped"])
        cache = {
            "pos": jnp.int32(S),
            "grouped_ssm": {"conv_buf": g_caches[0], "state": g_caches[1]},
            "shared_attn": {"k": attn_kv[0], "v": attn_kv[1]},
        }
        if self.tail:
            x, t_caches = pscan(capture_mamba, x, params["tail"])
            cache["tail_ssm"] = {"conv_buf": t_caches[0], "state": t_caches[1]}
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x[:, -1:],
                            self._head(params).astype(self.cdtype))
        return logits.astype(jnp.float32), cache

    def decode_step(self, params, cache, tokens) -> Tuple[jnp.ndarray, Pytree]:
        cfg, sh = self.cfg, self.sh
        x = params["embed"][tokens].astype(self.cdtype)
        pos = cache["pos"]

        def mamba_step(x, pl, cg):
            h = rms_norm(x, pl["ln"], cfg.norm_eps)
            out, new_c = mamba_decode_step(
                pl["ssm"], h, SSMCache(cg["conv_buf"], cg["state"]),
                self.scfg, sh, self.cdtype)
            return x + out, {"conv_buf": new_c.conv_buf, "state": new_c.state}

        def group_fn(x, inp):
            pg, cg = inp

            def inner(x, inp2):
                pl, cl = inp2
                x, nc = mamba_step(x, pl, cl)
                return x, nc

            x, new_ssm = pscan(
                inner, x, ({"ln": pg["ln"], "ssm": pg["ssm"]}, cg["ssm"]))
            # shared attention application with this group's cache
            s = params["shared"]
            h = rms_norm(x, s["ln1"], cfg.norm_eps)
            seq_shard = cg["attn"]["k"].shape[1] >= _SEQ_SHARD_MIN
            a, nk, nv = decode_attention(s["attn"], h, cg["attn"]["k"],
                                         cg["attn"]["v"], pos, self.acfg, sh,
                                         self.cdtype, seq_shard=seq_shard)
            x = x + a
            h = rms_norm(x, s["ln2"], cfg.norm_eps)
            x = x + mlp_apply(s["mlp"], h, sh, self.cdtype)
            return x, {"ssm": new_ssm, "attn": {"k": nk, "v": nv}}

        g_cache = {"ssm": {"conv_buf": cache["grouped_ssm"]["conv_buf"],
                           "state": cache["grouped_ssm"]["state"]},
                   "attn": cache["shared_attn"]}
        x, new_g = pscan(group_fn, x, (params["grouped"], g_cache))
        new_cache = {
            "pos": pos + 1,
            "grouped_ssm": new_g["ssm"],
            "shared_attn": new_g["attn"],
        }
        if self.tail:
            def tail_fn(x, inp):
                pl, cl = inp
                return mamba_step(x, pl, cl)
            x, new_t = pscan(tail_fn, x,
                                    (params["tail"], cache["tail_ssm"]))
            new_cache["tail_ssm"] = new_t
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            self._head(params).astype(self.cdtype))
        return logits.astype(jnp.float32), new_cache


# ---------------------------------------------------------------------------
# Pure SSM LM (mamba2): one scan over mamba blocks, O(1) decode state.
# ---------------------------------------------------------------------------


class SSMLM:
    def __init__(self, cfg: ModelConfig, sh: Optional[ShardPlan] = None):
        self.cfg = cfg
        self.sh = sh or ShardPlan()
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.cdtype = jnp.dtype(cfg.compute_dtype)
        self.scfg = SSMConfig(
            d_model=cfg.d_model, d_inner=cfg.d_inner, n_heads=cfg.n_ssm_heads,
            head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
            conv_dim=cfg.ssm_conv_dim, chunk=cfg.ssm_chunk)

    def init(self, key) -> Pytree:
        cfg = self.cfg
        L, D = cfg.n_layers, cfg.d_model
        ks = jax.random.split(key, 3)
        params = {
            "embed": embed_init(ks[0], cfg.padded_vocab, D, self.dtype),
            "layers": {"ln": jnp.ones((L, D), self.dtype),
                       "ssm": ssm_init(ks[1], L, self.scfg, self.dtype)},
            "final_norm": jnp.ones((D,), self.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[2], (D, cfg.padded_vocab), self.dtype)
        return params

    def param_specs(self) -> Pytree:
        cfg, sh = self.cfg, self.sh
        tp, fs = sh.tp, sh.fsdp
        ssm = {
            "w_z": P(None, fs, tp), "w_x": P(None, fs, tp),
            "w_B": P(None, fs, None), "w_C": P(None, fs, None),
            "w_dt": P(None, fs, tp),
            "conv_x": P(None, None, tp), "conv_B": P(None, None, None),
            "conv_C": P(None, None, None),
            "A_log": P(None, tp), "D": P(None, tp), "dt_bias": P(None, tp),
            "out_proj": P(None, tp, fs), "gate_norm": P(None, tp),
        }
        specs = {
            "embed": P(tp, fs),
            "layers": {"ln": P(None, None), "ssm": ssm},
            "final_norm": P(None),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = P(fs, tp)
        return specs

    def forward(self, params, tokens) -> jnp.ndarray:
        cfg, sh = self.cfg, self.sh
        x = params["embed"][tokens].astype(self.cdtype)
        x = shard(x, sh.dp, None, sh.tp)

        def body(x, pl):
            h = rms_norm(x, pl["ln"], cfg.norm_eps)
            x = x + mamba_block(pl["ssm"], h, self.scfg, sh, self.cdtype)
            return shard(x, sh.dp, None, sh.tp), None

        fn = body
        if cfg.remat:
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = pscan(fn, x, params["layers"])
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def _head(self, params):
        return params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]

    def loss_fn(self, params, batch) -> jnp.ndarray:
        hidden = self.forward(params, batch["tokens"])
        return chunked_ce_loss(hidden, self._head(params).astype(self.cdtype),
                               batch["labels"], batch.get("loss_mask"),
                               self.sh, remat=self.cfg.remat)

    def make_cache(self, batch: int, seq_len: int) -> Pytree:
        cfg = self.cfg
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        return {
            "pos": jnp.zeros((), jnp.int32),
            "ssm": {"conv_buf": jnp.zeros(
                        (cfg.n_layers, batch, cfg.ssm_conv_dim - 1, conv_ch),
                        self.cdtype),
                    "state": jnp.zeros(
                        (cfg.n_layers, batch, cfg.n_ssm_heads,
                         cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)},
        }

    def cache_specs(self, seq_len: int, batch: int = 0) -> Pytree:
        sh = self.sh
        dp = None if 0 < batch < 16 else sh.dp
        return {"pos": P(),
                "ssm": {"conv_buf": P(None, dp, None, None),
                        "state": P(None, dp, sh.tp, None, None)}}

    def grow_cache(self, cache: Pytree, target_len: int) -> Pytree:
        """Pure-SSM cache is O(1); nothing grows."""
        return cache

    def prefill(self, params, tokens) -> Tuple[jnp.ndarray, Pytree]:
        cfg, sh = self.cfg, self.sh
        x = params["embed"][tokens].astype(self.cdtype)
        x = shard(x, sh.dp, None, sh.tp)
        B, S, _ = x.shape
        from repro.models.ssm import _causal_conv, ssd_chunked
        import jax.nn as jnn
        cd = self.cdtype

        def body(x, pl):
            h = rms_norm(x, pl["ln"], cfg.norm_eps)
            hc = h.astype(cd)
            z = jnp.einsum("bsd,dk->bsk", hc, pl["ssm"]["w_z"].astype(cd))
            xs = jnp.einsum("bsd,dk->bsk", hc, pl["ssm"]["w_x"].astype(cd))
            Bm = jnp.einsum("bsd,dn->bsn", hc, pl["ssm"]["w_B"].astype(cd))
            Cm = jnp.einsum("bsd,dn->bsn", hc, pl["ssm"]["w_C"].astype(cd))
            dt = jnp.einsum("bsd,dh->bsh", hc, pl["ssm"]["w_dt"].astype(cd))
            conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
            tail = conv_in[:, S - (cfg.ssm_conv_dim - 1):, :]
            xs = jnn.silu(_causal_conv(xs, pl["ssm"]["conv_x"].astype(cd)))
            Bm = jnn.silu(_causal_conv(Bm, pl["ssm"]["conv_B"].astype(cd)))
            Cm = jnn.silu(_causal_conv(Cm, pl["ssm"]["conv_C"].astype(cd)))
            dt = jnn.softplus(dt.astype(jnp.float32)
                              + pl["ssm"]["dt_bias"][None, None, :])
            A = -jnp.exp(pl["ssm"]["A_log"])
            xs4 = xs.reshape(B, S, self.scfg.n_heads, self.scfg.head_dim)
            y, fin = ssd_chunked(xs4, dt, A, Bm, Cm, pl["ssm"]["D"],
                                 self.scfg.chunk, sh=sh)
            y = y.reshape(B, S, cfg.d_inner)
            y = rms_norm(y * jnn.silu(z.astype(jnp.float32)).astype(y.dtype),
                         pl["ssm"]["gate_norm"])
            out = jnp.einsum("bsk,kd->bsd", y.astype(cd),
                             pl["ssm"]["out_proj"].astype(cd))
            x = x + shard(out, sh.dp, None, None)
            return shard(x, sh.dp, None, sh.tp), (tail.astype(self.cdtype), fin)

        x, (convs, states) = pscan(body, x, params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x[:, -1:],
                            self._head(params).astype(cd))
        cache = {"pos": jnp.int32(S),
                 "ssm": {"conv_buf": convs, "state": states}}
        return logits.astype(jnp.float32), cache

    def decode_step(self, params, cache, tokens) -> Tuple[jnp.ndarray, Pytree]:
        cfg, sh = self.cfg, self.sh
        x = params["embed"][tokens].astype(self.cdtype)

        def body(x, inp):
            pl, cl = inp
            h = rms_norm(x, pl["ln"], cfg.norm_eps)
            out, nc = mamba_decode_step(
                pl["ssm"], h, SSMCache(cl["conv_buf"], cl["state"]),
                self.scfg, sh, self.cdtype)
            return x + out, {"conv_buf": nc.conv_buf, "state": nc.state}

        x, new_ssm = pscan(body, x, (params["layers"], cache["ssm"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            self._head(params).astype(self.cdtype))
        return logits.astype(jnp.float32), {"pos": cache["pos"] + 1,
                                            "ssm": new_ssm}


# ---------------------------------------------------------------------------


def _stack2(tree: Pytree, a: int, b: int) -> Pytree:
    """Reshape leading (a*b, ...) to (a, b, ...)."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape((a, b) + x.shape[1:]), tree)


def _squeeze(tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x[0], tree)
