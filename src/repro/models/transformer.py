"""Decoder-only LM covering the dense / moe / vlm families.

Structure: token (+ optional patch-prefix) embedding -> scan over layer
GROUPS -> final norm -> (tied) LM head.  A "group" is the layer repeat
unit: 1 for uniform archs, 2 for gemma2 (local, global) alternation.
Scanning groups keeps per-layer-type KV caches shape-uniform (local
layers get ring caches of length ``window``; global layers full-length).

Loss is computed with a sequence-chunked LM head (scan over S blocks) so
(B, S, vocab) logits are never materialized for the 256k-vocab archs.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.attention import AttnConfig, attn_init, attention, decode_attention
from repro.models.layers import (
    pscan,
    ShardPlan,
    chunked_ce_loss,
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    rms_norm,
    shard,
    softcap,
)

Pytree = Any

__all__ = ["DecoderLM"]

_LOSS_CHUNK = 512           # sequence chunk for the LM-head loss
_SEQ_SHARD_MIN = 8192       # decode caches at/above this length shard on seq


def _attn_cfg(cfg: ModelConfig, *, local: bool) -> AttnConfig:
    return AttnConfig(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd,
        rope_theta=cfg.rope_theta,
        rope_fraction=cfg.rope_fraction,
        window=cfg.window if local else None,
        softcap=cfg.attn_logit_softcap,
        qk_norm=cfg.qk_norm,
        causal=True,
    )


class DecoderLM:
    """Functional model bundle for one config (dense / moe / vlm)."""

    def __init__(self, cfg: ModelConfig, sh: Optional[ShardPlan] = None):
        self.cfg = cfg
        self.sh = sh or ShardPlan()
        # Layer grouping: gemma2 alternates (local, global).
        if cfg.local_global_every:
            self.group = 2
            self.layer_kinds = ("local", "global")
        else:
            self.group = 1
            self.layer_kinds = ("local" if cfg.window else "global",)
        assert cfg.n_layers % self.group == 0
        self.n_groups = cfg.n_layers // self.group
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.cdtype = jnp.dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------ init

    def init(self, key) -> Pytree:
        cfg = self.cfg
        NG, D, Vp = self.n_groups, cfg.d_model, cfg.padded_vocab
        keys = jax.random.split(key, 8)
        blocks = {}
        for gi, kind in enumerate(self.layer_kinds):
            acfg = _attn_cfg(cfg, local=(kind == "local"))
            sub = {
                "ln1": jnp.ones((NG, D), self.dtype),
                "ln2": jnp.ones((NG, D), self.dtype),
                "attn": attn_init(jax.random.fold_in(keys[0], gi), NG, D,
                                  acfg, self.dtype),
            }
            if cfg.sandwich_norm:
                sub["ln1_post"] = jnp.ones((NG, D), self.dtype)
                sub["ln2_post"] = jnp.ones((NG, D), self.dtype)
            if cfg.n_experts:
                sub["moe"] = moe_mod.moe_init(
                    jax.random.fold_in(keys[1], gi), NG, D, cfg.n_experts,
                    cfg.d_ff_expert, self.dtype)
            else:
                sub["mlp"] = mlp_init(jax.random.fold_in(keys[2], gi), NG, D,
                                      cfg.d_ff, self.dtype)
            blocks[f"g{gi}"] = sub
        params = {
            "embed": embed_init(keys[3], Vp, D, self.dtype),
            "blocks": blocks,
            "final_norm": jnp.ones((D,), self.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[4], (D, Vp), self.dtype)
        if cfg.family == "vlm":
            params["patch_proj"] = dense_init(keys[5], (cfg.frontend_dim, D),
                                              self.dtype)
        return params

    # ------------------------------------------------------------- specs

    def param_specs(self) -> Pytree:
        """PartitionSpec tree mirroring init() (for pjit in_shardings)."""
        cfg, sh = self.cfg, self.sh
        tp, fs = sh.tp, sh.fsdp
        blocks = {}
        for gi, kind in enumerate(self.layer_kinds):
            attn = {
                "wq": P(None, fs, tp),
                "wk": P(None, fs, tp),
                "wv": P(None, fs, tp),
                "wo": P(None, tp, fs),
            }
            if cfg.qk_norm:
                attn["q_norm"] = P(None, None)
                attn["k_norm"] = P(None, None)
            sub = {"ln1": P(None, None), "ln2": P(None, None), "attn": attn}
            if cfg.sandwich_norm:
                sub["ln1_post"] = P(None, None)
                sub["ln2_post"] = P(None, None)
            if cfg.n_experts:
                ep = cfg.n_experts % 16 == 0  # EP when experts divide the TP axis
                sub["moe"] = {
                    "router": P(None, fs, None),
                    "w_gate": P(None, tp, fs, None) if ep else P(None, None, fs, tp),
                    "w_up": P(None, tp, fs, None) if ep else P(None, None, fs, tp),
                    "w_down": P(None, tp, None, fs) if ep else P(None, None, tp, fs),
                }
            else:
                sub["mlp"] = {
                    "w_gate": P(None, fs, tp),
                    "w_up": P(None, fs, tp),
                    "w_down": P(None, tp, fs),
                }
            blocks[f"g{gi}"] = sub
        specs = {
            "embed": P(tp, fs),
            "blocks": blocks,
            "final_norm": P(None),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = P(fs, tp)
        if cfg.family == "vlm":
            specs["patch_proj"] = P(None, fs)
        return specs

    # ----------------------------------------------------------- embedding

    def _embed(self, params, tokens, patches=None):
        cfg, sh = self.cfg, self.sh
        x = params["embed"][tokens]                      # (B, S_text, D)
        if cfg.scale_embed:
            x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
        if patches is not None:
            pp = jnp.einsum("bpf,fd->bpd", patches.astype(self.cdtype),
                            params["patch_proj"].astype(self.cdtype))
            x = jnp.concatenate([pp.astype(x.dtype), x], axis=1)
        return shard(x.astype(self.cdtype), sh.dp, None, sh.tp)

    # ------------------------------------------------------------- forward

    def _group_body(self, params_g, x, positions, gi_kind):
        """One layer of kind gi_kind; params_g has NO leading group dim."""
        cfg, sh = self.cfg, self.sh
        acfg = _attn_cfg(cfg, local=(gi_kind == "local"))
        h = rms_norm(x, params_g["ln1"], cfg.norm_eps)
        a = attention(params_g["attn"], h, acfg, sh, self.cdtype,
                      positions=positions)
        if cfg.sandwich_norm:
            a = rms_norm(a, params_g["ln1_post"], cfg.norm_eps)
        x = x + a
        h = rms_norm(x, params_g["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            m = moe_mod.moe_apply(params_g["moe"], h, top_k=cfg.top_k,
                                  n_experts=cfg.n_experts,
                                  capacity_factor=1.25, sh=sh,
                                  compute_dtype=self.cdtype,
                                  bulk_steal=cfg.moe_bulk_steal,
                                  impl=cfg.moe_impl)
        else:
            m = mlp_apply(params_g["mlp"], h, sh, self.cdtype)
        if cfg.sandwich_norm:
            m = rms_norm(m, params_g["ln2_post"], cfg.norm_eps)
        x = x + m
        return shard(x, sh.dp, None, sh.tp)

    def forward(self, params, tokens, patches=None,
                positions=None) -> jnp.ndarray:
        """(B, S) tokens -> (B, S_total, D) hidden (after final norm)."""
        cfg, sh = self.cfg, self.sh
        x = self._embed(params, tokens, patches)
        S = x.shape[1]
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)

        def group_fn(x, params_group):
            for gi, kind in enumerate(self.layer_kinds):
                x = self._group_body(params_group[f"g{gi}"], x, positions, kind)
            return x, None

        body = group_fn
        if cfg.remat:
            body = jax.checkpoint(
                group_fn, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = pscan(body, x, params["blocks"])
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    # --------------------------------------------------------------- loss

    def _head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def loss_fn(self, params, batch) -> jnp.ndarray:
        """batch: tokens (B,S), labels (B,S), optional loss_mask, patches.

        The LM head + CE runs in sequence chunks (layers.chunked_ce_loss)
        so (B, S, V) is never materialized (V up to 256k).
        """
        cfg, sh = self.cfg, self.sh
        tokens = batch["tokens"]
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        patches = batch.get("patches")
        hidden = self.forward(params, tokens, patches)
        if patches is not None:
            hidden = hidden[:, patches.shape[1]:]  # loss over text positions
        head = self._head(params).astype(self.cdtype)
        return chunked_ce_loss(hidden, head, labels, mask, sh,
                               final_softcap=cfg.final_logit_softcap,
                               chunk=_LOSS_CHUNK, remat=cfg.remat)

    # ------------------------------------------------------------- serving

    def cache_len(self, kind: str, seq_len: int) -> int:
        if kind == "local" and self.cfg.window:
            return min(self.cfg.window, seq_len)
        return seq_len

    def make_cache(self, batch: int, seq_len: int) -> Pytree:
        """Zeroed KV caches, one stack per layer kind, + position scalar."""
        cfg = self.cfg
        NG = self.n_groups
        cache = {"pos": jnp.zeros((), jnp.int32)}
        for gi, kind in enumerate(self.layer_kinds):
            C = self.cache_len(kind, seq_len)
            cache[f"g{gi}"] = {
                "k": jnp.zeros((NG, batch, C, cfg.n_kv_heads, cfg.hd), self.cdtype),
                "v": jnp.zeros((NG, batch, C, cfg.n_kv_heads, cfg.hd), self.cdtype),
            }
        return cache

    def cache_specs(self, seq_len: int, batch: int = 0) -> Pytree:
        """PartitionSpecs for the cache.

        batch >= 16: batch shards over dp, long seqs additionally over tp.
        batch == 1 (long_500k): batch is unshardable — the sequence dim
        shards over (dp + tp) combined instead (full SP).
        """
        sh = self.sh
        tiny_batch = 0 < batch < 16
        specs = {"pos": P()}
        for gi, kind in enumerate(self.layer_kinds):
            C = self.cache_len(kind, seq_len)
            if tiny_batch:
                kv = P(None, None, tuple(sh.dp) + (sh.tp,), None, None)
            elif C >= _SEQ_SHARD_MIN:
                kv = P(None, sh.dp, sh.tp, None, None)
            else:
                kv = P(None, sh.dp, None, None, None)
            specs[f"g{gi}"] = {"k": kv, "v": kv}
        return specs

    def grow_cache(self, cache: Pytree, target_len: int) -> Pytree:
        """Grow a prefill cache for decoding up to ``target_len`` total
        positions.  Global (linear) caches zero-pad on the seq axis; local
        RING caches re-layout from C=min(window, S) to C=min(window,
        target) preserving the ``slot = pos % C`` invariant."""
        pos = cache["pos"]
        new = {"pos": pos}
        for gi, kind in enumerate(self.layer_kinds):
            cg = cache[f"g{gi}"]
            C = cg["k"].shape[2]
            C_new = self.cache_len(kind, target_len)
            if C_new <= C:
                new[f"g{gi}"] = cg
                continue
            if kind == "local" and self.cfg.window:
                # ring re-layout: slots hold positions [pos-C, pos)
                p = pos - C + jnp.arange(C, dtype=jnp.int32)
                src = p % C
                dst = p % C_new

                def relay(x):
                    out = jnp.zeros(x.shape[:2] + (C_new,) + x.shape[3:],
                                    x.dtype)
                    return out.at[:, :, dst].set(x[:, :, src])

                new[f"g{gi}"] = {"k": relay(cg["k"]), "v": relay(cg["v"])}
            else:
                padw = [(0, 0)] * cg["k"].ndim
                padw[2] = (0, C_new - C)
                new[f"g{gi}"] = {"k": jnp.pad(cg["k"], padw),
                                 "v": jnp.pad(cg["v"], padw)}
        return new

    def prefill(self, params, tokens, patches=None) -> Tuple[jnp.ndarray, Pytree]:
        """Forward over the prompt; returns (last-position logits, cache)."""
        cfg, sh = self.cfg, self.sh
        x = self._embed(params, tokens, patches)
        B, S, D = x.shape
        positions = jnp.arange(S, dtype=jnp.int32)
        caches = {"pos": jnp.int32(S)}

        def group_fn(x, params_group):
            kvs = {}
            for gi, kind in enumerate(self.layer_kinds):
                pg = params_group[f"g{gi}"]
                acfg = _attn_cfg(cfg, local=(kind == "local"))
                h = rms_norm(x, pg["ln1"], cfg.norm_eps)
                a, (k, v) = attention(pg["attn"], h, acfg, sh, self.cdtype,
                                      positions=positions, return_kv=True)
                C = self.cache_len(kind, S)
                if C < S:  # ring layout: slot = pos % C over the last C steps
                    ridx = jnp.arange(S - C, S, dtype=jnp.int32) % C
                    k = jnp.zeros((B, C) + k.shape[2:], k.dtype).at[:, ridx].set(k[:, S - C:])
                    v = jnp.zeros((B, C) + v.shape[2:], v.dtype).at[:, ridx].set(v[:, S - C:])
                kvs[f"g{gi}"] = {"k": k.astype(self.cdtype), "v": v.astype(self.cdtype)}
                if cfg.sandwich_norm:
                    a = rms_norm(a, pg["ln1_post"], cfg.norm_eps)
                x = x + a
                h = rms_norm(x, pg["ln2"], cfg.norm_eps)
                if cfg.n_experts:
                    m = moe_mod.moe_apply(pg["moe"], h, top_k=cfg.top_k,
                                          n_experts=cfg.n_experts,
                                          capacity_factor=1.25, sh=sh,
                                          compute_dtype=self.cdtype,
                                          bulk_steal=cfg.moe_bulk_steal,
                                          impl=cfg.moe_impl)
                else:
                    m = mlp_apply(pg["mlp"], h, sh, self.cdtype)
                if cfg.sandwich_norm:
                    m = rms_norm(m, pg["ln2_post"], cfg.norm_eps)
                x = x + m
                x = shard(x, sh.dp, None, sh.tp)
            return x, kvs

        x, kvs = pscan(group_fn, x, params["blocks"])
        caches.update(kvs)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        last = x[:, -1:]
        logits = jnp.einsum("bsd,dv->bsv", last,
                            self._head(params).astype(self.cdtype))
        logits = softcap(logits, cfg.final_logit_softcap)
        return logits.astype(jnp.float32), caches

    def decode_step(self, params, cache, tokens) -> Tuple[jnp.ndarray, Pytree]:
        """One-token decode. tokens: (B, 1). Returns (logits (B,1,V), cache)."""
        cfg, sh = self.cfg, self.sh
        x = self._embed(params, tokens)
        pos = cache["pos"]
        new_cache = {"pos": pos + 1}

        def group_fn(carry, inp):
            x = carry
            params_group, cache_group = inp
            new_kvs = {}
            for gi, kind in enumerate(self.layer_kinds):
                pg = params_group[f"g{gi}"]
                cg = cache_group[f"g{gi}"]
                acfg = _attn_cfg(cfg, local=(kind == "local"))
                seq_shard = cg["k"].shape[1] >= _SEQ_SHARD_MIN
                h = rms_norm(x, pg["ln1"], cfg.norm_eps)
                out3 = None
                if seq_shard and cfg.decode_impl == "flash_shardmap":
                    out3 = attn_mod.decode_attention_shardmap(
                        pg["attn"], h, cg["k"], cg["v"], pos, acfg, sh,
                        self.cdtype)
                if out3 is not None:
                    a, nk, nv = out3
                else:
                    a, nk, nv = decode_attention(pg["attn"], h, cg["k"],
                                                 cg["v"], pos, acfg, sh,
                                                 self.cdtype,
                                                 seq_shard=seq_shard)
                new_kvs[f"g{gi}"] = {"k": nk, "v": nv}
                if cfg.sandwich_norm:
                    a = rms_norm(a, pg["ln1_post"], cfg.norm_eps)
                x = x + a
                h = rms_norm(x, pg["ln2"], cfg.norm_eps)
                if cfg.n_experts:
                    m = moe_mod.moe_apply(pg["moe"], h, top_k=cfg.top_k,
                                          n_experts=cfg.n_experts,
                                          capacity_factor=2.0, sh=sh,
                                          compute_dtype=self.cdtype,
                                          bulk_steal=cfg.moe_bulk_steal,
                                          impl=cfg.moe_impl)
                else:
                    m = mlp_apply(pg["mlp"], h, sh, self.cdtype)
                if cfg.sandwich_norm:
                    m = rms_norm(m, pg["ln2_post"], cfg.norm_eps)
                x = x + m
            return x, new_kvs

        layer_caches = {k: v for k, v in cache.items() if k != "pos"}
        x, new_kvs = pscan(group_fn, x, (params["blocks"], layer_caches))
        new_cache.update(new_kvs)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            self._head(params).astype(self.cdtype))
        logits = softcap(logits, cfg.final_logit_softcap)
        return logits.astype(jnp.float32), new_cache
