"""GQA attention with RoPE / sliding-window / softcap / q-k norm + KV cache.

One implementation covers every assigned attention flavour:

* GQA with any (n_heads, n_kv_heads) ratio — KV heads are broadcast over
  query groups (Megatron-style; KV heads replicate across TP when
  ``n_kv_heads < tp``).
* RoPE with a configurable rotary fraction (chatglm3's "2d" RoPE rotates
  half of each head; everyone else uses fraction 1.0) and theta.
* Sliding-window masks (mixtral, gemma2 local layers) and full-causal.
* Gemma2 attention-logit soft-capping and qwen3 per-head q/k RMSNorm.
* Cross-attention (seamless enc-dec) — no causal mask, KV from encoder.
* Decode with a ring KV cache for windowed layers (cache length
  ``min(window, seq)``) and a linear cache otherwise.

The jnp path below is the lowering/compile reference; on TPU the
``kernels/flash_attention`` Pallas kernel implements the same math with
VMEM block tiling (selected via ``ModelConfig.use_pallas``).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (ShardPlan, _active_mesh, dense_init,
                                 rms_norm, shard, softcap, pscan)

Pytree = Any

__all__ = [
    "AttnConfig",
    "attn_init",
    "rope",
    "attention",
    "decode_attention",
    "KVCache",
]


class AttnConfig(NamedTuple):
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0
    window: Optional[int] = None          # None => full causal
    softcap: Optional[float] = None
    qk_norm: bool = False
    causal: bool = True                   # False for encoder / cross attn


def attn_init(key, L: int, d_model: int, cfg: AttnConfig, dtype) -> Pytree:
    """Parameters for L stacked layers (L==1 ⇒ squeeze by caller if wanted)."""
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (L, d_model, H * hd), dtype),
        "wk": dense_init(ks[1], (L, d_model, K * hd), dtype),
        "wv": dense_init(ks[2], (L, d_model, K * hd), dtype),
        "wo": dense_init(ks[3], (L, H * hd, d_model), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((L, hd), dtype)
        p["k_norm"] = jnp.ones((L, hd), dtype)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         fraction: float = 1.0) -> jnp.ndarray:
    """Apply rotary embedding to the first ``fraction`` of each head.

    x: (B, S, H, hd); positions: (B, S) or (S,).
    """
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]   # (S, half)
        ang = ang[None, :, None, :]                                      # (1,S,1,half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs           # (B,S,half)
        ang = ang[:, :, None, :]                                         # (B,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: Optional[int]) -> jnp.ndarray:
    """(Sq, Sk) additive bias: 0 where attendable, -inf elsewhere."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


# ---------------------------------------------------------------------------
# core attention (training / prefill)
# ---------------------------------------------------------------------------

# KV lengths at or above this use the lax.scan flash-style path so the
# (S, T) logits tensor is never materialized (prefill_32k would otherwise
# need ~17 GB/device of logits; even train_4k's direct path holds ~8 GiB
# of f32 logits per device).  The Pallas kernel replaces this on TPU.
_BLOCKED_KV_THRESHOLD = 4096
_KV_BLOCK = 1024


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """GQA -> flat heads: (B,T,K,hd) -> (B,T,H,hd), Megatron-style KV-head
    replication.  H is divisible by the 16-way TP axis for every assigned
    arch (K often is not), so sharding stays conflict-free; each TP rank
    only materializes the expanded heads it owns."""
    K = k.shape[2]
    if K == n_heads:
        return k
    return jnp.repeat(k, n_heads // K, axis=2)


def _blocked_attention(q, k, v, q_pos, k_pos, causal, window, cap,
                       compute_dtype, sh: ShardPlan):
    """Flash-style attention: scan over KV blocks with running (m, l, acc).

    q: (B,S,H,hd); k, v: (B,T,H,hd) (already expanded). Returns (B,S,H,hd).
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    nb = T // _KV_BLOCK
    kb = jnp.moveaxis(k.reshape(B, nb, _KV_BLOCK, H, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, _KV_BLOCK, H, hd), 1, 0)
    kpb = k_pos.reshape(nb, _KV_BLOCK)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def step(carry, inp):
        m, l, acc = carry
        kc, vc, kp = inp
        logits = jnp.einsum("bshd,bthd->bhst", q, kc).astype(jnp.float32)
        logits = logits * scale
        logits = softcap(logits, cap)
        logits = shard(logits, sh.dp, sh.tp, None, None)
        ok = jnp.ones((S, _KV_BLOCK), bool)
        if causal:
            ok &= kp[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= kp[None, :] > q_pos[:, None] - window
        logits = jnp.where(ok[None, None], logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # guard: fully-masked rows keep m = -inf; exp(-inf - -inf) -> use safe m
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_safe[..., None])
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhst,bthd->bshd", p.astype(compute_dtype), vc)
        acc_new = acc * jnp.moveaxis(corr, 1, 2)[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, S, H, hd), jnp.float32)
    # Remat each block step: scan-bwd then saves only the small (m, l, acc)
    # carries + the kv block slices instead of stacked f32 logits/masks
    # (those stacked residuals were ~2 GiB/device at train_4k).
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = pscan(step, (m0, l0, a0), (kb, vb, kpb))
    denom = jnp.moveaxis(jnp.maximum(l, 1e-30), 1, 2)[..., None]
    return (acc / denom).astype(compute_dtype)


def attention(p: Pytree, x: jnp.ndarray, cfg: AttnConfig, sh: ShardPlan,
              compute_dtype, positions: Optional[jnp.ndarray] = None,
              kv_x: Optional[jnp.ndarray] = None,
              kv_positions: Optional[jnp.ndarray] = None,
              return_kv: bool = False):
    """Full attention over a (B, S, D) block.

    kv_x: source for K/V (cross-attention); defaults to x (self-attention).
    Returns (B, S, D) output, optionally also the (k, v) tensors for cache
    construction during prefill.
    """
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    Sk = src.shape[1]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = positions if kv_x is None else jnp.arange(Sk, dtype=jnp.int32)

    xc = x.astype(compute_dtype)
    sc = src.astype(compute_dtype)
    q = jnp.einsum("bsd,dh->bsh", xc, p["wq"].astype(compute_dtype)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", sc, p["wk"].astype(compute_dtype)).reshape(B, Sk, K, hd)
    v = jnp.einsum("bsd,dh->bsh", sc, p["wv"].astype(compute_dtype)).reshape(B, Sk, K, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope_fraction > 0 and kv_x is None:  # no RoPE on cross-attn
        q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = rope(k, kv_positions, cfg.rope_theta, cfg.rope_fraction)

    q = shard(q, sh.dp, None, sh.tp, None)
    k0, v0 = k, v  # unexpanded (B,T,K,hd) — what a KV cache stores
    # GQA: expand KV to flat H heads (Megatron-style; see _expand_kv).
    k = shard(_expand_kv(k, H), sh.dp, None, sh.tp, None)
    v = shard(_expand_kv(v, H), sh.dp, None, sh.tp, None)

    q_pos = positions if positions.ndim == 1 else positions[0]
    k_pos = kv_positions if kv_positions.ndim == 1 else kv_positions[0]
    causal = cfg.causal and kv_x is None
    if Sk >= _BLOCKED_KV_THRESHOLD and Sk % _KV_BLOCK == 0:
        o = _blocked_attention(q, k, v, q_pos, k_pos, causal, cfg.window,
                               cfg.softcap, compute_dtype, sh)
        o = o.reshape(B, S, H * hd)
    else:
        logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
        logits = logits / jnp.sqrt(hd).astype(jnp.float32)
        logits = softcap(logits, cfg.softcap)
        logits = shard(logits, sh.dp, sh.tp, None, None)
        bias = _mask_bias(q_pos, k_pos, causal, cfg.window)
        logits = logits + bias[None, None]
        w = jax.nn.softmax(logits, axis=-1).astype(compute_dtype)
        o = jnp.einsum("bhst,bthd->bshd", w, v).reshape(B, S, H * hd)
    o = shard(o, sh.dp, None, sh.tp)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(compute_dtype))
    out = shard(out, sh.dp, None, None)
    if return_kv:
        return out, (k0, v0)
    return out


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Per-layer-stack KV cache.

    k, v: (L, B, C, K, hd) where C = cache length (= min(window, seq) for
    windowed layers — a RING buffer indexed mod C — else seq).
    """

    k: jnp.ndarray
    v: jnp.ndarray

    @property
    def length(self) -> int:
        return self.k.shape[2]


def make_cache(L: int, B: int, C: int, cfg: AttnConfig, dtype) -> KVCache:
    K, hd = cfg.n_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((L, B, C, K, hd), dtype),
        v=jnp.zeros((L, B, C, K, hd), dtype),
    )


def decode_attention_shardmap(p, x, cache_k, cache_v, pos, cfg: AttnConfig,
                              sh: ShardPlan, compute_dtype):
    """Flash-decoding via shard_map (§Perf optimized variant).

    The GSPMD path updates a sequence-sharded cache with a dynamic-index
    DUS, which the SPMD partitioner handles by REPLICATING the whole
    cache ("involuntary full rematerialization") — reading and writing
    O(cache) bytes per token.  Here the cache stays sharded over the TP
    axis on the sequence dim and each rank:

      1. locally writes the new KV iff it owns slot ``pos`` (no comm);
      2. computes attention over ITS seq shard with a local max/sum;
      3. merges across ranks with one tiny LSE psum (flash-decoding).

    Wire bytes per layer-step: O(B * H * hd) for the merge — independent
    of the cache length.  Falls back to None when no mesh is active.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = _active_mesh()
    if m is None or cfg.window is not None:
        return None
    tp = sh.tp
    dp = tuple(a for a in (sh.dp if isinstance(sh.dp, (tuple, list))
                           else (sh.dp,)) if a in m.axis_names)
    if tp not in m.axis_names:
        return None
    tp_size = m.shape[tp]
    B, _, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    C = cache_k.shape[1]
    if C % tp_size:
        return None
    C_loc = C // tp_size
    batch_sharded = bool(dp) and B % (max(1, _axes_size(m, dp))) == 0 and B >= 16
    bspec = dp if batch_sharded else None

    def local_fn(pl, xl, ck, cv, pos_):
        rank = jax.lax.axis_index(tp)
        Bl = xl.shape[0]
        xc = xl.astype(compute_dtype)
        q = jnp.einsum("bsd,dh->bsh", xc, pl["wq"].astype(compute_dtype)
                       ).reshape(Bl, 1, H, hd)
        k = jnp.einsum("bsd,dh->bsh", xc, pl["wk"].astype(compute_dtype)
                       ).reshape(Bl, 1, K, hd)
        v = jnp.einsum("bsd,dh->bsh", xc, pl["wv"].astype(compute_dtype)
                       ).reshape(Bl, 1, K, hd)
        if cfg.qk_norm:
            q = rms_norm(q, pl["q_norm"])
            k = rms_norm(k, pl["k_norm"])
        if cfg.rope_fraction > 0:
            pvec = jnp.full((1,), pos_, jnp.int32)
            q = rope(q, pvec, cfg.rope_theta, cfg.rope_fraction)
            k = rope(k, pvec, cfg.rope_theta, cfg.rope_fraction)

        # 1. local ring write: only the owner rank mutates its shard.
        owner = pos_ // C_loc
        mine = rank == owner
        slot = jnp.where(mine, pos_ % C_loc, 0)
        cur_k = jax.lax.dynamic_slice(ck, (0, slot, 0, 0), (Bl, 1, K, hd))
        cur_v = jax.lax.dynamic_slice(cv, (0, slot, 0, 0), (Bl, 1, K, hd))
        wk_ = jnp.where(mine, k.astype(ck.dtype), cur_k)
        wv_ = jnp.where(mine, v.astype(cv.dtype), cur_v)
        ck = jax.lax.dynamic_update_slice(ck, wk_, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, wv_, (0, slot, 0, 0))

        # 2. local attention over this rank's seq shard.
        base = rank * C_loc
        idx = base + jnp.arange(C_loc, dtype=jnp.int32)
        valid = idx <= pos_
        G = H // K
        qg = q.reshape(Bl, K, G, hd)
        logits = jnp.einsum("bkgh,btkh->bkgt", qg,
                            ck.astype(compute_dtype)).astype(jnp.float32)
        logits = logits / jnp.sqrt(hd).astype(jnp.float32)
        logits = softcap(logits, cfg.softcap)
        logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
        m_loc = jnp.max(logits, axis=-1)                      # (B,K,G)
        m_glob = jax.lax.pmax(m_loc, tp)
        m_safe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
        p_ = jnp.exp(logits - m_safe[..., None])
        p_ = jnp.where(valid[None, None, None, :], p_, 0.0)
        l_loc = jnp.sum(p_, axis=-1)                          # (B,K,G)
        o_loc = jnp.einsum("bkgt,btkh->bkgh", p_.astype(compute_dtype),
                           cv.astype(compute_dtype)).astype(jnp.float32)
        # 3. one LSE merge: psum of (l, o) — O(B*H*hd) wire bytes.
        l_glob = jax.lax.psum(l_loc, tp)
        o_glob = jax.lax.psum(o_loc, tp)
        o = (o_glob / jnp.maximum(l_glob, 1e-30)[..., None])
        o = o.reshape(Bl, 1, H * hd).astype(compute_dtype)
        out = jnp.einsum("bsh,hd->bsd", o, pl["wo"].astype(compute_dtype))
        return out, ck, cv

    pspec = {k_: P(None, None) for k_ in ("wq", "wk", "wv", "wo")}
    if cfg.qk_norm:
        pspec["q_norm"] = P(None)
        pspec["k_norm"] = P(None)
    cache_spec = P(bspec, tp, None, None)
    fn = shard_map(
        local_fn, mesh=m,
        in_specs=(pspec, P(bspec, None, None), cache_spec, cache_spec, P()),
        out_specs=(P(bspec, None, None), cache_spec, cache_spec),
        check_rep=False)
    return fn(p, x, cache_k, cache_v, pos)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def decode_attention(p: Pytree, x: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, pos: jnp.ndarray, cfg: AttnConfig,
                     sh: ShardPlan, compute_dtype,
                     seq_shard: bool = False):
    """One-token decode for a single layer.

    x: (B, 1, D); cache_k/v: (B, C, K, hd); pos: scalar int32 — the absolute
    position of the new token.  For windowed layers the cache is a ring
    (C == window) written at ``pos % C``; otherwise linear (C == max seq).

    seq_shard: constrain the cache's sequence dim over the TP axis
    (sequence parallelism for long-context decode; GSPMD turns the softmax
    reduction into a psum — flash-decoding-style partial-max merging is the
    §Perf optimized variant via shard_map).

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B, _, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    C = cache_k.shape[1]

    xc = x.astype(compute_dtype)
    q = jnp.einsum("bsd,dh->bsh", xc, p["wq"].astype(compute_dtype)).reshape(B, 1, H, hd)
    k = jnp.einsum("bsd,dh->bsh", xc, p["wk"].astype(compute_dtype)).reshape(B, 1, K, hd)
    v = jnp.einsum("bsd,dh->bsh", xc, p["wv"].astype(compute_dtype)).reshape(B, 1, K, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope_fraction > 0:
        pvec = jnp.full((1,), pos, jnp.int32)
        q = rope(q, pvec, cfg.rope_theta, cfg.rope_fraction)
        k = rope(k, pvec, cfg.rope_theta, cfg.rope_fraction)

    slot = jnp.where(cfg.window is not None, pos % C, jnp.minimum(pos, C - 1))
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, slot, 0, 0))
    if seq_shard:
        sp = tuple(sh.dp) + (sh.tp,)
        cache_k = shard(cache_k, None, sp, None, None)
        cache_v = shard(cache_v, None, sp, None, None)
    else:
        cache_k = shard(cache_k, sh.dp, None, None, None)
        cache_v = shard(cache_v, sh.dp, None, None, None)

    # Validity of cache slots: ring ⇒ last `window` positions; linear ⇒ <= pos.
    idx = jnp.arange(C, dtype=jnp.int32)
    if cfg.window is not None:
        # slot i holds absolute position: the ring wraps every C steps.
        age = (slot - idx) % C           # 0 == newest
        valid = age <= jnp.minimum(pos, C - 1)
    else:
        valid = idx <= pos

    G = H // K
    qg = q.reshape(B, K, G, hd)
    kc = cache_k.astype(compute_dtype)
    vc = cache_v.astype(compute_dtype)
    logits = jnp.einsum("bkgh,btkh->bkgt", qg, kc).astype(jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    logits = softcap(logits, cfg.softcap)
    logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1).astype(compute_dtype)
    o = jnp.einsum("bkgt,btkh->bkgh", w, vc).reshape(B, 1, H * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(compute_dtype))
    return out, cache_k, cache_v
