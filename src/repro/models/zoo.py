"""Model zoo: config -> model bundle + input_specs per shape cell.

``build_model(cfg, parallel)`` dispatches on family; every model bundle
exposes: init / loss_fn / prefill / decode_step / param_specs /
(make_cache, cache_specs).

``input_specs(cfg, shape, parallel)`` returns ShapeDtypeStructs (weak-type
correct, shardable, never allocated) for the dry-run, plus the matching
PartitionSpec tree for in_shardings.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.hybrid import HybridLM, SSMLM
from repro.models.layers import ShardPlan
from repro.models.transformer import DecoderLM

Pytree = Any

__all__ = ["build_model", "input_specs", "batch_specs"]


def build_model(cfg: ModelConfig, parallel: ParallelConfig | None = None):
    sh = ShardPlan.from_parallel(parallel) if parallel else ShardPlan()
    if cfg.family in ("decoder", "moe", "vlm"):
        return DecoderLM(cfg, sh)
    if cfg.family == "encdec":
        return EncDecLM(cfg, sh)
    if cfg.family == "ssm":
        return SSMLM(cfg, sh)
    if cfg.family == "hybrid":
        return HybridLM(cfg, sh)
    raise ValueError(f"unknown family {cfg.family!r}")


# ---------------------------------------------------------------------------
# input specs per shape cell (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                parallel: ParallelConfig | None = None
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (batch_sds, batch_pspecs) for the given (arch, shape) cell.

    train:   tokens/labels (B, S) [+ patches / frames per family]
    prefill: tokens (B, S) [+ patches / frames]
    decode:  tokens (B, 1); the KV/SSM cache specs come from the model
             bundle's make_cache/cache_specs (handled in launch.dryrun).
    """
    B, S = shape.global_batch, shape.seq_len
    dp = parallel.batch_axes if parallel else ("data",)
    i32, f32 = jnp.int32, jnp.float32

    if shape.kind == "train":
        if cfg.family == "vlm":
            S_text = S - cfg.n_patches
            sds = {
                "tokens": _sds((B, S_text), i32),
                "labels": _sds((B, S_text), i32),
                "patches": _sds((B, cfg.n_patches, cfg.frontend_dim), f32),
            }
            ps = {"tokens": P(dp, None), "labels": P(dp, None),
                  "patches": P(dp, None, None)}
        elif cfg.family == "encdec":
            sds = {
                "frames": _sds((B, S, cfg.frontend_dim), f32),
                "tokens": _sds((B, S), i32),
                "labels": _sds((B, S), i32),
            }
            ps = {"frames": P(dp, None, None), "tokens": P(dp, None),
                  "labels": P(dp, None)}
        else:
            sds = {"tokens": _sds((B, S), i32), "labels": _sds((B, S), i32)}
            ps = {"tokens": P(dp, None), "labels": P(dp, None)}
        return sds, ps

    if shape.kind == "prefill":
        if cfg.family == "vlm":
            S_text = S - cfg.n_patches
            sds = {
                "tokens": _sds((B, S_text), i32),
                "patches": _sds((B, cfg.n_patches, cfg.frontend_dim), f32),
            }
            ps = {"tokens": P(dp, None), "patches": P(dp, None, None)}
        elif cfg.family == "encdec":
            sds = {"frames": _sds((B, S, cfg.frontend_dim), f32),
                   "tokens": _sds((B, S), i32)}
            ps = {"frames": P(dp, None, None), "tokens": P(dp, None)}
        else:
            sds = {"tokens": _sds((B, S), i32)}
            ps = {"tokens": P(dp, None)}
        return sds, ps

    # decode: one new token against a seq_len cache.  A batch of 1
    # (long_500k) cannot shard over the batch axes — replicate it.
    sds = {"tokens": _sds((B, 1), i32)}
    ps = {"tokens": P(dp, None) if B >= 16 else P(None, None)}
    return sds, ps


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                parallel: ParallelConfig | None = None):
    """Alias kept for the benchmark harness."""
    return input_specs(cfg, shape, parallel)
