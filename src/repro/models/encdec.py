"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the assignment: inputs are precomputed
frame embeddings (B, S_enc, frontend_dim) which a learned projection maps
to d_model.  The backbone is a standard pre-norm transformer enc-dec:
bidirectional encoder, causal decoder with cross-attention.

Decode: self-attn KV cache grows per step; cross-attn K/V are computed
once from the encoder output and stay fixed.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.attention import AttnConfig, attn_init, attention, decode_attention
from repro.models.layers import (
    pscan,
    ShardPlan,
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    rms_norm,
    shard,
)

Pytree = Any

__all__ = ["EncDecLM"]


class EncDecLM:
    def __init__(self, cfg: ModelConfig, sh: Optional[ShardPlan] = None):
        self.cfg = cfg
        self.sh = sh or ShardPlan()
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.cdtype = jnp.dtype(cfg.compute_dtype)

    def _acfg(self, causal: bool, rope: bool = True) -> AttnConfig:
        cfg = self.cfg
        return AttnConfig(
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta,
            rope_fraction=cfg.rope_fraction if rope else 0.0,
            window=None, softcap=None, qk_norm=False, causal=causal)

    # ------------------------------------------------------------------ init

    def init(self, key) -> Pytree:
        cfg = self.cfg
        D, Vp = cfg.d_model, cfg.padded_vocab
        Le, Ld = cfg.n_encoder_layers, cfg.n_layers
        ks = jax.random.split(key, 8)
        enc = {
            "ln1": jnp.ones((Le, D), self.dtype),
            "ln2": jnp.ones((Le, D), self.dtype),
            "attn": attn_init(ks[0], Le, D, self._acfg(False), self.dtype),
            "mlp": mlp_init(ks[1], Le, D, cfg.d_ff, self.dtype),
        }
        dec = {
            "ln1": jnp.ones((Ld, D), self.dtype),
            "ln_x": jnp.ones((Ld, D), self.dtype),
            "ln2": jnp.ones((Ld, D), self.dtype),
            "attn": attn_init(ks[2], Ld, D, self._acfg(True), self.dtype),
            "xattn": attn_init(ks[3], Ld, D, self._acfg(False), self.dtype),
            "mlp": mlp_init(ks[4], Ld, D, cfg.d_ff, self.dtype),
        }
        return {
            "frontend_proj": dense_init(ks[5], (cfg.frontend_dim, D), self.dtype),
            "encoder": enc,
            "enc_norm": jnp.ones((D,), self.dtype),
            "decoder": dec,
            "embed": embed_init(ks[6], Vp, D, self.dtype),
            "final_norm": jnp.ones((D,), self.dtype),
            "lm_head": dense_init(ks[7], (D, Vp), self.dtype),
        }

    def param_specs(self) -> Pytree:
        sh = self.sh
        tp, fs = sh.tp, sh.fsdp
        attn = {"wq": P(None, fs, tp), "wk": P(None, fs, tp),
                "wv": P(None, fs, tp), "wo": P(None, tp, fs)}
        mlp = {"w_gate": P(None, fs, tp), "w_up": P(None, fs, tp),
               "w_down": P(None, tp, fs)}
        return {
            "frontend_proj": P(None, fs),
            "encoder": {"ln1": P(None, None), "ln2": P(None, None),
                        "attn": dict(attn), "mlp": dict(mlp)},
            "enc_norm": P(None),
            "decoder": {"ln1": P(None, None), "ln_x": P(None, None),
                        "ln2": P(None, None), "attn": dict(attn),
                        "xattn": dict(attn), "mlp": dict(mlp)},
            "embed": P(tp, fs),
            "final_norm": P(None),
            "lm_head": P(fs, tp),
        }

    # --------------------------------------------------------------- encoder

    def encode(self, params, frames) -> jnp.ndarray:
        cfg, sh = self.cfg, self.sh
        x = jnp.einsum("bsf,fd->bsd", frames.astype(self.cdtype),
                       params["frontend_proj"].astype(self.cdtype))
        x = shard(x, sh.dp, None, sh.tp)
        acfg = self._acfg(False)

        def body(x, pl):
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            x = x + attention(pl["attn"], h, acfg, sh, self.cdtype)
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            x = x + mlp_apply(pl["mlp"], h, sh, self.cdtype)
            return shard(x, sh.dp, None, sh.tp), None

        fn = body
        if cfg.remat:
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = pscan(fn, x, params["encoder"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # --------------------------------------------------------------- decoder

    def _decoder_forward(self, params, tokens, enc_out) -> jnp.ndarray:
        cfg, sh = self.cfg, self.sh
        x = params["embed"][tokens].astype(self.cdtype)
        x = shard(x, sh.dp, None, sh.tp)
        self_cfg, x_cfg = self._acfg(True), self._acfg(False, rope=False)

        def body(x, pl):
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            x = x + attention(pl["attn"], h, self_cfg, sh, self.cdtype)
            h = rms_norm(x, pl["ln_x"], cfg.norm_eps)
            x = x + attention(pl["xattn"], h, x_cfg, sh, self.cdtype,
                              kv_x=enc_out)
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            x = x + mlp_apply(pl["mlp"], h, sh, self.cdtype)
            return shard(x, sh.dp, None, sh.tp), None

        fn = body
        if cfg.remat:
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = pscan(fn, x, params["decoder"])
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    # ------------------------------------------------------------------ loss

    def loss_fn(self, params, batch) -> jnp.ndarray:
        from repro.models.layers import chunked_ce_loss

        enc_out = self.encode(params, batch["frames"])
        hidden = self._decoder_forward(params, batch["tokens"], enc_out)
        head = params["lm_head"].astype(self.cdtype)
        return chunked_ce_loss(hidden, head, batch["labels"],
                               batch.get("loss_mask"), self.sh,
                               chunk=512, remat=self.cfg.remat)

    # --------------------------------------------------------------- serving

    def make_cache(self, batch: int, seq_len: int, enc_len: int) -> Pytree:
        cfg = self.cfg
        Ld = cfg.n_layers
        K, hd = cfg.n_kv_heads, cfg.hd
        return {
            "pos": jnp.zeros((), jnp.int32),
            "self": {"k": jnp.zeros((Ld, batch, seq_len, K, hd), self.cdtype),
                     "v": jnp.zeros((Ld, batch, seq_len, K, hd), self.cdtype)},
            "cross": {"k": jnp.zeros((Ld, batch, enc_len, K, hd), self.cdtype),
                      "v": jnp.zeros((Ld, batch, enc_len, K, hd), self.cdtype)},
        }

    def cache_specs(self, seq_len: int, batch: int = 0) -> Pytree:
        sh = self.sh
        if 0 < batch < 16:
            kv = P(None, None, tuple(sh.dp) + (sh.tp,), None, None)
        elif seq_len >= 8192:
            kv = P(None, sh.dp, sh.tp, None, None)
        else:
            kv = P(None, sh.dp, None, None, None)
        return {"pos": P(), "self": {"k": kv, "v": kv},
                "cross": {"k": kv, "v": kv}}

    def grow_cache(self, cache: Pytree, target_len: int) -> Pytree:
        """Self-attn cache is linear: zero-pad; cross cache fixed."""
        sc = cache["self"]
        C = sc["k"].shape[2]
        if C >= target_len:
            return cache
        padw = [(0, 0)] * sc["k"].ndim
        padw[2] = (0, target_len - C)
        return {"pos": cache["pos"], "cross": cache["cross"],
                "self": {"k": jnp.pad(sc["k"], padw),
                         "v": jnp.pad(sc["v"], padw)}}

    def prefill(self, params, frames, tokens) -> Tuple[jnp.ndarray, Pytree]:
        """Encode source; run decoder over the target prefix; build caches."""
        cfg, sh = self.cfg, self.sh
        enc_out = self.encode(params, frames)
        B, S = tokens.shape
        x = params["embed"][tokens].astype(self.cdtype)
        x = shard(x, sh.dp, None, sh.tp)
        positions = jnp.arange(S, dtype=jnp.int32)
        self_cfg, x_cfg = self._acfg(True), self._acfg(False, rope=False)

        def body(x, pl):
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            a, (sk, sv) = attention(pl["attn"], h, self_cfg, sh, self.cdtype,
                                    positions=positions, return_kv=True)
            x = x + a
            h = rms_norm(x, pl["ln_x"], cfg.norm_eps)
            a, (ck, cv) = attention(pl["xattn"], h, x_cfg, sh, self.cdtype,
                                    kv_x=enc_out, return_kv=True)
            x = x + a
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            x = x + mlp_apply(pl["mlp"], h, sh, self.cdtype)
            kv = {"self": {"k": sk.astype(self.cdtype), "v": sv.astype(self.cdtype)},
                  "cross": {"k": ck.astype(self.cdtype), "v": cv.astype(self.cdtype)}}
            return shard(x, sh.dp, None, sh.tp), kv

        x, kvs = pscan(body, x, params["decoder"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x[:, -1:],
                            params["lm_head"].astype(self.cdtype))
        cache = {"pos": jnp.int32(S), "self": kvs["self"], "cross": kvs["cross"]}
        return logits.astype(jnp.float32), cache

    def decode_step(self, params, cache, tokens) -> Tuple[jnp.ndarray, Pytree]:
        cfg, sh = self.cfg, self.sh
        x = params["embed"][tokens].astype(self.cdtype)
        pos = cache["pos"]
        self_cfg, x_cfg = self._acfg(True), self._acfg(False, rope=False)

        def body(x, inp):
            pl, cg = inp
            seq_shard = cg["self"]["k"].shape[1] >= 8192
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            a, nk, nv = decode_attention(pl["attn"], h, cg["self"]["k"],
                                         cg["self"]["v"], pos, self_cfg, sh,
                                         self.cdtype, seq_shard=seq_shard)
            x = x + a
            h = rms_norm(x, pl["ln_x"], cfg.norm_eps)
            # Cross-attn over the fixed encoder KV: full (non-causal) read.
            ck, cv = cg["cross"]["k"], cg["cross"]["v"]
            B = x.shape[0]
            H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            q = jnp.einsum("bsd,dh->bsh", h.astype(self.cdtype),
                           pl["xattn"]["wq"].astype(self.cdtype)).reshape(B, 1, H, hd)
            G = H // K
            qg = q.reshape(B, K, G, hd)
            logits = jnp.einsum("bkgh,btkh->bkgt", qg,
                                ck.astype(self.cdtype)).astype(jnp.float32)
            logits = logits / jnp.sqrt(hd).astype(jnp.float32)
            w = jax.nn.softmax(logits, axis=-1).astype(self.cdtype)
            o = jnp.einsum("bkgt,btkh->bkgh", w,
                           cv.astype(self.cdtype)).reshape(B, 1, H * hd)
            x = x + jnp.einsum("bsh,hd->bsd", o,
                               pl["xattn"]["wo"].astype(self.cdtype))
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            x = x + mlp_apply(pl["mlp"], h, sh, self.cdtype)
            return x, {"k": nk, "v": nv}

        layer_caches = (params["decoder"],
                        {"self": cache["self"], "cross": cache["cross"]})
        x, new_self = pscan(body, x, layer_caches)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(self.cdtype))
        new_cache = {"pos": pos + 1, "self": new_self, "cross": cache["cross"]}
        return logits.astype(jnp.float32), new_cache
