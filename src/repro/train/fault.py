"""Fault tolerance: signal-triggered checkpoints, straggler detection,
and a supervised restart loop.

On a real multi-pod deployment each host runs the same SPMD program; the
pieces here are the per-host controls that make a 1000-node run survivable:

* ``GracefulExit`` — SIGTERM/SIGINT set a flag; the train loop checks it
  once per step and writes a final checkpoint before exiting (preemption
  handling on TPU pods, where eviction sends SIGTERM).
* ``StragglerMonitor`` — EMA of step wall-time; a step slower than
  ``threshold x`` the EMA marks this host as a straggler.  The hook is
  wired to the data pipeline's bulk-steal rebalancing (a slow host's
  unread work is stolen by the master — the paper's mechanism applied to
  input data), and the decision is exported for external orchestrators
  that replace chronically slow hosts.
* ``run_supervised`` — restart-on-crash wrapper: run the train loop; on
  an unhandled exception, restore from the latest checkpoint and resume,
  up to ``max_restarts`` (node-failure recovery; with a cluster manager
  the same entrypoint simply re-executes on a replacement node).
"""

from __future__ import annotations

import signal
import time
import traceback
from typing import Callable, Optional

__all__ = ["GracefulExit", "StragglerMonitor", "run_supervised"]


class GracefulExit:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        self._signals = signals

    def _handler(self, signum, frame):
        self.requested = True

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


class StragglerMonitor:
    """EMA step timer; ``observe()`` returns True when this step was a
    straggler (> threshold x EMA)."""

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.straggler_steps = 0
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def observe(self) -> bool:
        if self._t0 is None:
            return False
        dt = time.monotonic() - self._t0
        self._t0 = None
        self.n += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = (self.n > self.warmup
                        and dt > self.threshold * self.ema)
        self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        if is_straggler:
            self.straggler_steps += 1
        return is_straggler


def run_supervised(run: Callable[[Optional[int]], int],
                   max_restarts: int = 3,
                   on_restart: Optional[Callable[[int, BaseException], None]] = None
                   ) -> int:
    """Call ``run(resume_step)``; on crash, retry from the latest
    checkpoint (run() is responsible for restoring when resume_step is
    not None).  Returns the final step."""
    resume: Optional[int] = None
    for attempt in range(max_restarts + 1):
        try:
            return run(resume)
        except (KeyboardInterrupt, SystemExit, GeneratorExit):
            # Deliberate shutdown paths, not crashes: swallowing
            # SystemExit would turn `sys.exit()` (e.g. a GracefulExit
            # handler deciding to stop) into a restart loop.
            raise
        except BaseException as e:  # noqa: BLE001 — restart-on-anything
            if attempt == max_restarts:
                raise
            traceback.print_exc()
            if on_restart is not None:
                on_restart(attempt, e)
            resume = -1  # sentinel: restore from latest
    raise RuntimeError("unreachable")
