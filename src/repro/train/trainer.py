"""Train-step builder: loss -> grads -> AdamW, with optional microbatch
gradient accumulation structured so XLA's latency-hiding scheduler can
overlap each microbatch's reduce-scatter with the next one's compute.

The returned function is pjit-ready: callers pass in_shardings built from
``model.param_specs()`` / ``opt_state_specs`` / the batch pspecs.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, OptState, adamw_update

Pytree = Any

__all__ = ["make_train_step", "TrainState"]


class TrainState:
    """Lightweight container (params, opt) — kept as a plain tuple pytree
    in the step function itself for pjit friendliness."""


def make_train_step(model, opt_cfg: AdamWConfig,
                    microbatch: int = 0) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    microbatch > 1 splits the batch leading dim into that many chunks and
    accumulates grads with a lax.scan (each chunk's backward ends in the
    FSDP reduce-scatter; the scan structure lets XLA overlap it with the
    next chunk's compute).
    """

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def train_step(params, opt_state: OptState, batch):
        if microbatch and microbatch > 1:
            def split(x):
                B = x.shape[0]
                return x.reshape((microbatch, B // microbatch) + x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mb_batch):
                acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb_batch)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, loss

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(acc_fn, zeros, mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatch, grads)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step
