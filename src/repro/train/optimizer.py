"""AdamW, pure-functional, with optimizer state sharded like the params.

The m/v moments are fp32 and inherit the parameter PartitionSpecs, so with
FSDP sharding the full optimizer state is sharded over (tp x fsdp) — the
ZeRO-style memory layout GSPMD gives for free.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "opt_state_specs", "cosine_lr"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    # Mixed precision: model params live in bf16 (HALVING every FSDP
    # all-gather and the live param bytes); the fp32 source of truth is
    # the ``master`` copy inside the optimizer state (sharded like m/v).
    master_weights: bool = False


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Pytree
    v: Pytree
    master: Pytree  # fp32 master copy (empty tuple when disabled)


def adamw_init(params: Pytree, *, master_weights: bool = False) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (jax.tree_util.tree_map(
        lambda p: p.astype(jnp.float32), params) if master_weights else ())
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree_util.tree_map(jnp.copy, zeros),
                    master=master)


def opt_state_specs(param_specs: Pytree, *, master_weights: bool = False) -> OptState:
    """Optimizer-state PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P

    return OptState(step=P(), m=param_specs,
                    v=jax.tree_util.tree_map(lambda s: s, param_specs),
                    master=(jax.tree_util.tree_map(lambda s: s, param_specs)
                            if master_weights else ()))


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def _global_norm(tree: Pytree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: Pytree, state: OptState,
                 params: Pytree) -> Tuple[Pytree, OptState, Pytree]:
    """Returns (new_params, new_state, metrics).

    With master_weights, the fp32 update applies to state.master and the
    (possibly bf16) params are a cast of it.
    """
    step = state.step + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    use_master = cfg.master_weights and state.master != ()

    def upd(p, g, m, v, pm):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        src = pm if use_master else p.astype(jnp.float32)
        if p.ndim >= 2:  # decay matrices, not norms/embedd... norms are 1-d
            delta = delta + cfg.weight_decay * src
        new_master = src - lr * delta
        return new_master.astype(p.dtype), m, v, new_master

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_pm = (treedef.flatten_up_to(state.master) if use_master
               else [None] * len(flat_p))
    out = [upd(p, g, m, v, pm)
           for p, g, m, v, pm in zip(flat_p, flat_g, flat_m, flat_v, flat_pm)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_master = (treedef.unflatten([o[3] for o in out]) if use_master
                  else ())
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, m=new_m, v=new_v,
                           master=new_master), metrics
