"""Atomic, elastic checkpoints.

Layout: <dir>/step_<N>/arrays.npz + meta.json, written to a tmp dir and
renamed (atomic on POSIX) so a crash mid-write never corrupts the latest
checkpoint.  ``keep`` old steps are retained.

Elastic restore: arrays are saved as full (unsharded) host arrays keyed
by pytree path, so a checkpoint written on one mesh restores onto ANY
mesh/topology — ``restore(..., shardings=...)`` places each leaf with
jax.device_put against the new mesh's NamedShardings (re-sharding a 256-
chip checkpoint onto 512 chips or onto 1 CPU for debugging).

Data-iterator state (a small dict) rides along in meta.json so resume
is exact, not approximate.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Pytree = Any

__all__ = ["save", "restore", "latest_step", "Checkpointer"]


def _entry_name(p: Any) -> str:
    """Stable name for one pytree-path entry.  DictKey carries ``.key``,
    SequenceKey ``.idx``, GetAttrKey (NamedTuples/dataclasses, e.g.
    ``QueueState``) ``.name`` — probe all three before falling back to
    ``str(p)``, whose reprs (``.buf`` vs ``GetAttrKey(name='buf')``)
    are not stable across jax versions."""
    for attr in ("key", "idx", "name"):
        v = getattr(p, attr, None)
        if v is not None:
            return str(v)
    return str(p)


def _path_key(path) -> str:
    return "/".join(_entry_name(p) for p in path)


def _legacy_path_key(path) -> str:
    # The pre-fix key (no ``.name`` probe): read-compat for checkpoints
    # written before GetAttrKey entries were named properly.
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_key(path)] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(template: Pytree, flat: Dict[str, np.ndarray]) -> Pytree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _path_key(path)
        arr = flat.get(key)
        if arr is None:
            arr = flat[_legacy_path_key(path)]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, tree: Pytree,
         extra: Optional[dict] = None, keep: int = 3) -> str:
    """Atomically write checkpoint for ``step``; GC old ones."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "extra": extra or {}}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # keep-k GC
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
    return final


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, template: Pytree, step: Optional[int] = None,
            shardings: Optional[Pytree] = None) -> Tuple[Pytree, int, dict]:
    """Load ``step`` (default: latest).  With ``shardings`` (a pytree of
    jax.sharding.Sharding matching template) each leaf is device_put onto
    the new mesh — the elastic-restore path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    tree = _unflatten(template, flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, int(meta["step"]), meta.get("extra", {})


class Checkpointer:
    """Convenience wrapper bundling directory, cadence, and keep-k."""

    def __init__(self, ckpt_dir: str, every: int = 100, keep: int = 3):
        self.dir = ckpt_dir
        self.every = max(every, 1)
        self.keep = keep

    def maybe_save(self, step: int, tree: Pytree,
                   extra: Optional[dict] = None) -> Optional[str]:
        if step % self.every == 0:
            return save(self.dir, step, tree, extra, keep=self.keep)
        return None
