"""Linearizability model checker for the bulk work-stealing queue.

The paper's correctness argument is an informal sketch: every operation
linearizes at a single cursor write (``size += n`` for the owner,
``lo += n`` for the stealer), so any concurrent history is equivalent to
some sequential one.  This module mechanizes the sketch as a small-step
operational model checked EXHAUSTIVELY on small geometries:

* the **shared object** is a real :class:`~repro.core.ops.QueueState`
  driven through a real backend (``reference`` / ``pallas`` / ``auto`` /
  ``relaxed`` — on CPU the kernel routings execute their jnp oracles,
  so all four backends are checkable everywhere);
* the **threads** are one owner (``push`` / ``pop`` / ``pop_bulk``) and
  one stealer (``steal`` / ``steal_exact``) — the paper's one-owner /
  one-stealer model.  Items carry unique int32 ids (0 is reserved for
  dead rows), so conservation is checked on identity, not counts;
* the **histories** are every interleaving of an owner script and a
  stealer script (a merge enumeration), from several seeded initial
  states including wrapped cursors, over small rings (``capacity <= 8``);
* the **oracle** is :class:`SeqSpec` — a python list model mirroring the
  clamp arithmetic of ``core/ops.py`` bit-for-bit (including the float32
  ``floor(size * (1 - proportion))`` of the paper's Listing-4 plan).

For the *fenced* backends every step is atomic, so the checker demands
EXACT linearizability: after each op, the returned count/batch/state
must equal the sequential spec's.

For the fence-free ``relaxed`` backend the steal is genuinely two steps
(:func:`repro.core.relaxed.optimistic_read`, then
:func:`~repro.core.relaxed.reconcile`), and owner steps may interleave
BETWEEN them.  The checker enforces the backend's weaker contract:

* ``size`` never negative, cursor bumps exactly by the settled count;
* transient over-claim bounded by ``multiplicity_bound(max_steal)``;
* **no lost items** and per-item multiplicity within the bound, on the
  tagged-id multiset over (escaped ∪ live) at the end of the history;
* **reconcile restores exactness**: the settle must equal a fenced
  ``steal_exact`` of the settled count against the owner's CURRENT
  state — the settled rows are real, current items, not stale bytes.

The reconcile's settle is clamped to the *stable-prefix floor* (the
minimum owner-visible size since the read — ``reconcile(..., floor=)``);
the deliberately broken variants in :data:`MUTATIONS` (no floor clamp /
no size clamp) exist to prove the checker CAN fail: ``--mutate`` runs
them and exits nonzero unless every mutation is caught.

CLI::

    python -m repro.analysis.linearize            # all 4 backends, exit 1 on violation
    python -m repro.analysis.linearize --quick    # smallest geometry only
    python -m repro.analysis.linearize --mutate   # seeded-bug detection proof
"""

from __future__ import annotations

import argparse
import itertools
import sys
from collections import Counter
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as bulk_ops
from repro.core import relaxed as relaxed_mod
from repro.core.ops import QueueState
from repro.analysis.sanitize import _mirror_steal_plan

__all__ = ["SeqSpec", "check_backend", "check_all", "run_mutations",
           "MUTATIONS", "FENCED_BACKENDS", "ALL_BACKENDS"]

FENCED_BACKENDS = ("reference", "pallas", "auto")
ALL_BACKENDS = FENCED_BACKENDS + ("relaxed",)

ITEM_SPEC = jax.ShapeDtypeStruct((), jnp.int32)
QUEUE_LIMIT = 0  # scripts drive tiny queues; no abort threshold noise


# ---------------------------------------------------------------------------
# The sequential specification
# ---------------------------------------------------------------------------


class SeqSpec:
    """The sequential queue: a python list, oldest first, mirroring the
    device ops' clamp arithmetic exactly."""

    def __init__(self, capacity: int, items: Sequence[int] = ()):
        self.capacity = int(capacity)
        self.items: List[int] = list(items)

    @property
    def size(self) -> int:
        return len(self.items)

    def push(self, ids: Sequence[int]) -> int:
        n = max(min(len(ids), self.capacity - len(self.items)), 0)
        self.items.extend(ids[:n])
        return n

    def pop(self) -> Optional[int]:
        return self.items.pop() if self.items else None

    def pop_bulk(self, max_n: int, n: int) -> List[int]:
        k = max(min(n, len(self.items), max_n), 0)
        block = self.items[len(self.items) - k:]
        del self.items[len(self.items) - k:]
        return block  # oldest-of-the-popped-block first, like the op

    def steal_front(self, k: int) -> List[int]:
        k = max(min(k, len(self.items)), 0)
        block = self.items[:k]
        del self.items[:k]
        return block

    def steal_exact(self, n: int, max_steal: int) -> List[int]:
        return self.steal_front(int(np.clip(n, 0,
                                            min(len(self.items),
                                                max_steal))))

    def steal(self, proportion: float, queue_limit: int,
              max_steal: int) -> List[int]:
        return self.steal_front(_mirror_steal_plan(
            len(self.items), proportion, queue_limit, max_steal))


# ---------------------------------------------------------------------------
# Device-side helpers
# ---------------------------------------------------------------------------


def _seed_state(capacity: int, ids: Sequence[int], lo: int) -> QueueState:
    """Build a concrete QueueState with the live block at an arbitrary
    cursor position (wrapped cursors are first-class histories)."""
    buf = np.zeros((capacity,), np.int32)
    for i, x in enumerate(ids):
        buf[(lo + i) % capacity] = x
    return QueueState(buf=jnp.asarray(buf), lo=jnp.int32(lo % capacity),
                      size=jnp.int32(len(ids)))


def _live_ids(q: QueueState) -> List[int]:
    cap = np.asarray(q.buf).shape[0]
    buf, lo, size = np.asarray(q.buf), int(q.lo), int(q.size)
    return [int(buf[(lo + i) % cap]) for i in range(size)]


def _batch_ids(batch, n: int) -> List[int]:
    return [int(x) for x in np.asarray(batch)[:n]]


def _dead_rows_zero(batch, n: int) -> bool:
    return not np.any(np.asarray(batch)[n:])


# ---------------------------------------------------------------------------
# Scripts and interleavings
# ---------------------------------------------------------------------------

# Owner ops: ("push", k) — k fresh ids; ("pop",); ("pop_bulk", max_n, n).
# Stealer ops: ("steal", p); ("steal_exact", n).


def owner_scripts(cap: int) -> List[List[tuple]]:
    return [
        [],
        [("push", 2)],
        [("pop",)],
        [("pop",), ("pop",)],
        [("push", cap)],                        # overfill: clamps to space
        [("pop",), ("push", 2)],                # dip-and-refill
        [("pop_bulk", 2, 2), ("push", 3)],      # deeper dip, slot reuse
        [("push", 1), ("pop",)],
    ]


def stealer_scripts(max_steal: int) -> List[List[tuple]]:
    return [
        [("steal_exact", 1)],
        [("steal_exact", max_steal)],
        [("steal", 0.5)],
        [("steal", 1.0)],
        [("steal_exact", 1), ("steal_exact", max_steal)],
    ]


def initial_states(cap: int) -> List[Tuple[int, int]]:
    """(seed_size, lo) pairs — empty, small, nearly full; straight and
    wrapped cursors."""
    return [(0, 0), (2, cap - 2), (cap - 1, 1)]


def expand_stealer(script: Sequence[tuple], split: bool
                   ) -> List[Tuple[str, tuple]]:
    """The stealer thread's atomic steps.  Fenced: one atomic step per
    steal.  Split (relaxed): each steal becomes TWO steps — the
    optimistic read and the reconcile — expanded BEFORE interleaving so
    owner mutations can land between them (the whole point of the
    relaxed model: the dip-and-refill schedules live in that gap)."""
    steps: List[Tuple[str, tuple]] = []
    for op in script:
        if split:
            steps.append(("read", op))
            steps.append(("reconcile", op))
        else:
            steps.append(("stealer", op))
    return steps


def interleavings(owner: Sequence[tuple],
                  stealer_steps: Sequence[Tuple[str, tuple]]):
    """Every merge of the two threads preserving per-thread order —
    owner ops are tagged here, stealer steps arrive pre-tagged (and
    pre-expanded, see :func:`expand_stealer`)."""
    total = len(owner) + len(stealer_steps)
    for owner_slots in itertools.combinations(range(total), len(owner)):
        slots = set(owner_slots)
        o = iter(owner)
        s = iter(stealer_steps)
        yield [("owner", next(o)) if i in slots else next(s)
               for i in range(total)]


# ---------------------------------------------------------------------------
# History execution
# ---------------------------------------------------------------------------


ReconcileFn = Callable[..., Tuple[QueueState, object, jnp.ndarray]]


def _default_reconcile(q, window, claim, max_steal, floor):
    return relaxed_mod.reconcile(q, window, claim, max_steal, floor=floor)


def _mut_no_floor(q, window, claim, max_steal, floor):
    """Seeded bug: reconcile against the current size only, ignoring the
    stable-prefix floor — dip-and-refill schedules hand out stale rows
    and lose the refilled items."""
    return relaxed_mod.reconcile(q, window, claim, max_steal, floor=None)


def _mut_no_size_clamp(q, window, claim, max_steal, floor):
    """Seeded bug: settle the raw claim clamped only to the static
    window — the deliberately broken multiplicity bound (size can go
    negative, over-claimed rows escape)."""
    cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
    n = jnp.clip(jnp.asarray(claim, jnp.int32), 0, jnp.int32(max_steal))
    offs = jnp.arange(max_steal, dtype=jnp.int32)
    batch = jax.tree_util.tree_map(
        lambda x: jnp.where((offs < n).reshape((max_steal,) + (1,) *
                                               (x.ndim - 1)),
                            x, jnp.zeros_like(x)), window)
    return QueueState(buf=q.buf, lo=(q.lo + n) % cap, size=q.size - n), \
        batch, n


MUTATIONS: Dict[str, ReconcileFn] = {
    "no-floor": _mut_no_floor,
    "no-size-clamp": _mut_no_size_clamp,
}


class _HistoryRun:
    """Execute one interleaving against one backend, mirroring the
    sequential spec, and collect violations (empty = linearizable)."""

    def __init__(self, ops: bulk_ops.BulkOps, ref: bulk_ops.BulkOps,
                 capacity: int, max_steal: int, seed: Tuple[int, int],
                 *, split_steals: bool,
                 reconcile_fn: ReconcileFn = _default_reconcile):
        self.ops, self.ref = ops, ref
        self.cap, self.ms = capacity, max_steal
        self.split = split_steals
        self.reconcile_fn = reconcile_fn
        n_seed, lo = seed
        seed_ids = list(range(1, n_seed + 1))
        self.next_id = n_seed + 1
        self.q = _seed_state(capacity, seed_ids, lo)
        self.spec = SeqSpec(capacity, seed_ids)
        self.exp_lo = lo % capacity
        self.pushed: List[int] = list(seed_ids)
        self.escaped: List[int] = []
        self.pending: Optional[dict] = None  # outstanding optimistic read
        self.violations: List[str] = []
        self.bound = (ops.multiplicity_bound(max_steal)
                      if hasattr(ops, "multiplicity_bound") else 0)

    def bad(self, msg: str) -> None:
        self.violations.append(msg)

    # -- shared postconditions ----------------------------------------------

    def _state_invariants(self, tag: str) -> None:
        size, lo = int(self.q.size), int(self.q.lo)
        if size < 0:
            self.bad(f"{tag}: size went NEGATIVE ({size})")
        if size > self.cap:
            self.bad(f"{tag}: size {size} exceeds capacity {self.cap}")
        if lo != self.exp_lo:
            self.bad(f"{tag}: cursor lo={lo}, expected {self.exp_lo} "
                     f"(linearization is the single cursor bump)")

    def _match_spec(self, tag: str) -> None:
        live = _live_ids(self.q)
        if live != self.spec.items:
            self.bad(f"{tag}: live queue {live} != spec {self.spec.items}")

    # -- owner steps ---------------------------------------------------------

    def owner_step(self, op: tuple) -> None:
        kind = op[0]
        if kind == "push":
            k = op[1]
            ids = list(range(self.next_id, self.next_id + k))
            self.next_id += k
            self.pushed.extend(ids)
            batch = jnp.asarray(np.asarray(ids, np.int32))
            self.q, n = self.ops.push(self.q, batch, jnp.int32(k))
            exp = self.spec.push(ids)
            if int(n) != exp:
                self.bad(f"push: n_pushed={int(n)}, spec says {exp}")
            # ids the clamp rejected never entered the object
            for lost in ids[exp:]:
                self.pushed.remove(lost)
        elif kind == "pop":
            self.q, item, valid = self.ops.pop(self.q)
            exp = self.spec.pop()
            if bool(valid) != (exp is not None):
                self.bad(f"pop: valid={bool(valid)}, spec "
                         f"{'has' if exp is not None else 'lacks'} an item")
            elif exp is not None:
                if int(item) != exp:
                    self.bad(f"pop: item {int(item)} != spec {exp}")
                self.escaped.append(int(item))
        elif kind == "pop_bulk":
            _, max_n, n_req = op
            self.q, batch, n = self.ops.pop_bulk(self.q, max_n,
                                                 jnp.int32(n_req))
            exp = self.spec.pop_bulk(max_n, n_req)
            got = _batch_ids(batch, int(n))
            if int(n) != len(exp) or got != exp:
                self.bad(f"pop_bulk: got {got} (n={int(n)}), spec {exp}")
            if not _dead_rows_zero(batch, int(n)):
                self.bad("pop_bulk: dead rows not zeroed")
            self.escaped.extend(got)
        else:  # pragma: no cover - script typo guard
            raise ValueError(f"unknown owner op {op}")
        self._state_invariants(f"owner {kind}")
        self._match_spec(f"owner {kind}")
        if self.pending is not None:
            self.pending["floor"] = min(self.pending["floor"],
                                        int(self.q.size))

    # -- stealer steps (fenced / atomic) -------------------------------------

    def fenced_steal(self, op: tuple) -> None:
        kind = op[0]
        if kind == "steal_exact":
            self.q, batch, n = self.ops.steal_exact(
                self.q, jnp.int32(op[1]), max_steal=self.ms)
            exp = self.spec.steal_exact(op[1], self.ms)
        else:
            self.q, batch, n = self.ops.steal(
                self.q, op[1], max_steal=self.ms, queue_limit=QUEUE_LIMIT)
            exp = self.spec.steal(op[1], QUEUE_LIMIT, self.ms)
        got = _batch_ids(batch, int(n))
        if int(n) != len(exp) or got != exp:
            self.bad(f"{kind}: stole {got} (n={int(n)}), spec {exp}")
        if not _dead_rows_zero(batch, int(n)):
            self.bad(f"{kind}: dead rows not zeroed")
        self.escaped.extend(got)
        self.exp_lo = (self.exp_lo + int(n)) % self.cap
        self._state_invariants(f"stealer {kind}")
        self._match_spec(f"stealer {kind}")

    # -- stealer steps (relaxed / split) -------------------------------------

    def relaxed_read(self, op: tuple) -> None:
        size = int(self.q.size)
        window = relaxed_mod.optimistic_read(self.q, self.ms)
        if op[0] == "steal_exact":
            claim = int(op[1])
        else:
            # Listing-4 claim arithmetic, unclamped (the fence-free read
            # consults no coherent bound).
            p = op[1]
            mult = np.float32(1.0 - float(p))
            keep = int(np.floor(np.float32(size) * mult))
            claim = 0 if size < QUEUE_LIMIT else size - keep
        over = min(max(claim, 0), self.ms)
        if over - min(over, size) > self.bound:
            self.bad(f"{op[0]} read: transient over-claim {over} beyond "
                     f"size {size} exceeds multiplicity bound {self.bound}")
        self.pending = {"window": np.asarray(window).copy(),
                        "claim": claim, "floor": size, "op": op[0]}

    def relaxed_reconcile(self) -> None:
        pend = self.pending
        self.pending = None
        size_now = int(self.q.size)
        q2, batch, n = self.reconcile_fn(
            self.q, jnp.asarray(pend["window"]), jnp.int32(pend["claim"]),
            self.ms, jnp.int32(pend["floor"]))
        n = int(n)
        tag = f"{pend['op']} reconcile"
        n_exp = min(int(np.clip(pend["claim"], 0, self.ms)),
                    max(pend["floor"], 0), size_now)
        if n != n_exp:
            self.bad(f"{tag}: settled n={n}, the stable-prefix contract "
                     f"says min(claim clamp, floor={pend['floor']}, "
                     f"size={size_now}) = {n_exp}")
        # The settle must be exactly a fenced steal of n CURRENT items.
        r_q, r_batch, r_n = self.ref.steal_exact(self.q, jnp.int32(n),
                                                 max_steal=self.ms)
        exp = self.spec.steal_front(min(max(n, 0), size_now))
        self.q = q2
        got = _batch_ids(batch, max(n, 0))
        if n != int(r_n) or got != _batch_ids(r_batch, int(r_n)) or got != exp:
            self.bad(f"{tag}: settled {got} (n={n}), fenced oracle says "
                     f"{_batch_ids(r_batch, int(r_n))} (n={int(r_n)}), "
                     f"spec {exp}")
        if n >= 0 and not _dead_rows_zero(batch, n):
            self.bad(f"{tag}: withdrawn rows not zeroed")
        claim_bounded = min(max(pend["claim"], 0), self.ms)
        if claim_bounded - max(n, 0) > self.bound:
            self.bad(f"{tag}: over-claim {claim_bounded - max(n, 0)} "
                     f"exceeds multiplicity bound {self.bound}")
        self.escaped.extend(got)
        self.exp_lo = (self.exp_lo + n) % self.cap
        self._state_invariants(tag)
        if int(self.q.size) >= 0:
            self._match_spec(tag)

    # -- drive ---------------------------------------------------------------

    def run(self, steps: Sequence[Tuple[str, tuple]]) -> List[str]:
        for role, op in steps:
            if role == "owner":
                self.owner_step(op)
            elif role == "stealer":
                self.fenced_steal(op)
            elif role == "read":
                self.relaxed_read(op)
            else:
                self.relaxed_reconcile()
            if self.violations:
                break  # first divergence is the story; stop early
        if not self.violations:
            self._conservation()
        return self.violations

    def _conservation(self) -> None:
        counts = Counter(self.escaped) + Counter(_live_ids(self.q))
        counts.pop(0, None)  # dead-row filler is not an item
        for item in self.pushed:
            mult = counts.get(item, 0)
            if mult == 0:
                self.bad(f"conservation: item {item} LOST")
            elif mult > max(self.bound, 1):
                self.bad(f"conservation: item {item} multiplicity {mult} "
                         f"exceeds bound {max(self.bound, 1)}")
        ghost = set(counts) - set(self.pushed)
        if ghost:
            self.bad(f"conservation: ghost items {sorted(ghost)} appeared")


# ---------------------------------------------------------------------------
# The sweep
# ---------------------------------------------------------------------------


def check_backend(backend: str, *, capacity: int, max_steal: int,
                  reconcile_fn: ReconcileFn = _default_reconcile,
                  max_violations: int = 10) -> Tuple[int, List[str]]:
    """Check every scripted history of one backend on one geometry.
    Returns ``(n_histories, violations)``; stops collecting after
    ``max_violations`` distinct failing histories."""
    ops = bulk_ops.make_ops(backend, capacity=capacity, max_push=capacity,
                            max_pop=capacity, max_steal=max_steal)
    ref = bulk_ops.make_ops("reference")
    # Split-step checking requires the genuinely optimistic routing (the
    # predicate-gated fallback is fenced reference under the same name).
    split = backend == "relaxed" and ops.resolved == "relaxed"
    n_hist = 0
    violations: List[str] = []
    for seed in initial_states(capacity):
        for o_script in owner_scripts(capacity):
            for s_script in stealer_scripts(max_steal):
                s_steps = expand_stealer(s_script, split)
                for steps in interleavings(o_script, s_steps):
                    n_hist += 1
                    run = _HistoryRun(ops, ref, capacity, max_steal, seed,
                                      split_steals=split,
                                      reconcile_fn=reconcile_fn)
                    bad = run.run(steps)
                    if bad:
                        trace = " ; ".join(f"{r}:{o[0]}" for r, o in steps)
                        violations.append(
                            f"[{backend} cap={capacity} ms={max_steal} "
                            f"seed={seed}] {trace} -> {bad[0]}")
                        if len(violations) >= max_violations:
                            return n_hist, violations
    return n_hist, violations


def check_all(backends: Sequence[str] = ALL_BACKENDS, *,
              geometries: Sequence[Tuple[int, int]] = ((4, 2), (8, 4)),
              verbose: bool = False) -> Tuple[int, List[str]]:
    total = 0
    violations: List[str] = []
    for cap, ms in geometries:
        for backend in backends:
            n, bad = check_backend(backend, capacity=cap, max_steal=ms)
            total += n
            violations.extend(bad)
            if verbose:
                status = "FAIL" if bad else "ok"
                print(f"  {backend:<10} cap={cap} max_steal={ms}: "
                      f"{n} histories {status}", flush=True)
    return total, violations


def run_mutations(*, capacity: int = 4, max_steal: int = 2,
                  verbose: bool = False) -> Dict[str, int]:
    """Run the relaxed histories under each seeded reconcile mutation;
    returns violations caught per mutation (every entry must be > 0 for
    the checker to be trusted)."""
    caught: Dict[str, int] = {}
    for name, fn in MUTATIONS.items():
        _, bad = check_backend("relaxed", capacity=capacity,
                               max_steal=max_steal, reconcile_fn=fn)
        caught[name] = len(bad)
        if verbose and bad:
            print(f"  mutation {name}: caught ({len(bad)} violating "
                  f"histories), e.g.\n    {bad[0]}", flush=True)
    return caught


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--backends", default=",".join(ALL_BACKENDS),
                        help="comma-separated backend names")
    parser.add_argument("--quick", action="store_true",
                        help="smallest geometry only (fast CI smoke)")
    parser.add_argument("--mutate", action="store_true",
                        help="assert the seeded reconcile mutations are "
                             "caught (exit 1 if any slips through)")
    parser.add_argument("-v", "--verbose", action="store_true")
    args = parser.parse_args(argv)

    if args.mutate:
        print("linearize --mutate: seeded relaxed-reconcile bugs must be "
              "caught ...", flush=True)
        caught = run_mutations(verbose=True)
        missed = [name for name, n in caught.items() if n == 0]
        if missed:
            print(f"CHECKER CANNOT FAIL: mutations {missed} produced no "
                  f"violations", flush=True)
            return 1
        print(f"ok: all {len(caught)} seeded mutations caught "
              f"({sum(caught.values())} violating histories)", flush=True)
        return 0

    backends = tuple(b for b in args.backends.split(",") if b)
    geometries = ((4, 2),) if args.quick else ((4, 2), (8, 4))
    total, violations = check_all(backends, geometries=geometries,
                                  verbose=True)
    if violations:
        print(f"\n{len(violations)} violating histor"
              f"{'y' if len(violations) == 1 else 'ies'} "
              f"(of {total}):", flush=True)
        for v in violations:
            print(f"  {v}", flush=True)
        return 1
    print(f"linearizable: {total} histories x {len(backends)} backend(s) "
          f"({', '.join(backends)}), no violations", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
