"""``repro.analysis`` — the machine-checked correctness layer.

The paper argues linearizability and lock-freedom *informally*; this
package turns the sketch into CI-enforced fact, three ways:

* :mod:`repro.analysis.linearize` — a small-step operational model of
  :class:`~repro.core.ops.QueueState` checked against a sequential
  specification over exhaustive owner/stealer interleavings on small
  geometries.  Exact linearizability for the fenced backends; the
  bounded-multiplicity contract for the fence-free ``relaxed`` backend.
* :mod:`repro.analysis.lint` — an AST-level static pass (no execution):
  kernel-package completeness (geometry predicate + jnp oracle + parity
  test), ``input_output_aliases`` ↔ ``donate=`` mirroring,
  use-after-donate, and leftover ``use_kernel``-era patterns.
* :mod:`repro.analysis.sanitize` — the runtime sanitizer: ``REPRO_CHECK=1``
  (or ``make_ops(..., check=True)``) wraps every backend op in invariant
  checks — conservation of tagged items, cursor monotonicity, dead rows
  zeroed, spill/refill accounting for :class:`~repro.core.queue.PagedQueue`.

Each pass has a CLI (``python -m repro.analysis.lint`` /
``python -m repro.analysis.linearize``) wired into the CI ``analysis``
lane; DESIGN.md §7 documents the model and what each check means.
"""

from repro.analysis.sanitize import (CheckedBulkOps, SanitizerError,
                                     assert_clean, checking_enabled,
                                     reset_violations, violations)

__all__ = ["CheckedBulkOps", "SanitizerError", "assert_clean",
           "checking_enabled", "reset_violations", "violations"]
