"""Runtime sanitizer: every queue op validated against its contract.

``REPRO_CHECK=1`` (or ``make_ops(..., check=True)``) makes
:func:`repro.core.ops.make_ops` wrap whatever backend it resolves in a
:class:`CheckedBulkOps`.  The wrapper delegates the real work to the
wrapped backend unchanged and validates the result against the
sequential contract the model checker (:mod:`repro.analysis.linearize`)
proves on small geometries — so production-sized runs get the same
invariants, spot-checked live:

* **concrete states** (host-driven calls: seeding, draining,
  ``PagedQueue`` paging, the model checker itself) get the FULL check —
  exact content conservation (the op's output rows are exactly the
  right slice of the input's live region), clamp arithmetic, cursor
  monotonicity (``lo' == (lo + n) % cap`` on the steal side, ``lo``
  frozen elsewhere), dead batch rows zeroed;
* **traced states** (inside ``jit``/``vmap``/``scan`` — the superstep
  and the fused round loop) get the scalar subset via
  ``jax.debug.callback``: count/cursor/bounds arithmetic per op, which
  survives batching (the callback sees stacked lanes and checks them
  all).

Violations are *recorded*, not raised from inside a trace (an exception
inside a callback would poison async dispatch): host checkpoints —
``StealRuntime.round`` / ``run_fused``, ``benchmarks/run.py --check``,
:func:`assert_clean` — drain the log and raise :class:`SanitizerError`.
Eager (concrete-path) violations raise immediately, naming the op.

The executor adds two cross-op checks when the sanitizer is on: per
round, the superstep must conserve ``sum(sizes)`` (flat mode), and for
pure rebalancing rounds (no worker body) the *multiset of live items*
across all lanes must be exactly preserved — the tagged-id conservation
argument of the paper, checked on real payload bytes.
"""

from __future__ import annotations

import functools
import os
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as bulk_ops
from repro.core.ops import QueueState

__all__ = [
    "CheckedBulkOps",
    "SanitizerError",
    "checking_enabled",
    "violations",
    "reset_violations",
    "assert_clean",
    "raise_pending",
    "record_violation",
    "check_round_stats",
    "trace_check_superstep",
    "queues_fingerprint",
    "check_conserved",
]

Pytree = Any


class SanitizerError(AssertionError):
    """A queue-op invariant did not hold at runtime."""


_VIOLATIONS: List[str] = []


def checking_enabled() -> bool:
    """Whether ``REPRO_CHECK`` asks for the sanitizer (the same switch
    :func:`repro.core.ops.make_ops` consults)."""
    return bulk_ops._env_check()


def violations() -> Tuple[str, ...]:
    return tuple(_VIOLATIONS)


def reset_violations() -> None:
    _VIOLATIONS.clear()


def record_violation(msg: str, *, eager: bool = False) -> None:
    """Log one violation.  ``eager=True`` (host-path checks) raises
    immediately; traced checks only record — a checkpoint raises."""
    _VIOLATIONS.append(msg)
    if eager:
        raise SanitizerError(msg)


def raise_pending(context: str) -> None:
    """Raise (and clear) any violations recorded since the last
    checkpoint — called by the executor after each dispatch completes,
    so traced-callback findings surface at a useful host frame."""
    if _VIOLATIONS:
        msgs = list(_VIOLATIONS)
        _VIOLATIONS.clear()
        raise SanitizerError(
            f"{len(msgs)} invariant violation(s) at {context}:\n  "
            + "\n  ".join(msgs))


def assert_clean() -> None:
    """Final checkpoint: raise if anything was recorded, else no-op."""
    raise_pending("assert_clean")


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _is_traced(*vals) -> bool:
    return any(isinstance(v, jax.core.Tracer) for v in vals)


def _capacity(q: QueueState) -> int:
    return jax.tree_util.tree_leaves(q.buf)[0].shape[0]


def _live_rows(q: QueueState) -> List[np.ndarray]:
    """Host copies of the live region per buffer leaf, queue order
    (oldest first) — snapshot BEFORE a donating call may invalidate."""
    cap = _capacity(q)
    lo, size = int(q.lo), int(q.size)
    idx = np.array([(lo + i) % cap for i in range(size)], np.int64)
    return [np.asarray(leaf)[idx].copy()
            for leaf in jax.tree_util.tree_leaves(q.buf)]


def _batch_rows(batch: Pytree, sl) -> List[np.ndarray]:
    return [np.asarray(leaf)[sl].copy()
            for leaf in jax.tree_util.tree_leaves(batch)]


def _rows_equal(a: Sequence[np.ndarray], b: Sequence[np.ndarray]) -> bool:
    return (len(a) == len(b)
            and all(x.shape == y.shape and np.array_equal(x, y)
                    for x, y in zip(a, b)))


def _concat(a: Sequence[np.ndarray], b: Sequence[np.ndarray]
            ) -> List[np.ndarray]:
    return [np.concatenate([x, y], axis=0) for x, y in zip(a, b)]


def _zero_rows(batch: Pytree, sl) -> bool:
    return all(not np.any(r) for r in _batch_rows(batch, sl))


def _mirror_steal_plan(size: int, proportion, queue_limit: int,
                       max_steal: int) -> int:
    """Host mirror of ``ops._steal_plan``'s float32 arithmetic (the
    relaxed claim settles to the identical count — see linearize)."""
    if isinstance(proportion, (int, float)):
        mult = np.float32(1.0 - float(proportion))
    else:  # concrete f32 scalar: subtract in f32 like the traced op
        mult = np.float32(1.0) - np.float32(np.asarray(proportion))
    keep = int(np.floor(np.float32(size) * mult))
    n = int(np.clip(size - keep, 0, min(size, max_steal)))
    return 0 if size < queue_limit else n


# ---------------------------------------------------------------------------
# Traced-path scalar checks (jax.debug.callback)
# ---------------------------------------------------------------------------


def _on_scalars(op: str, cap: int, lo_b, size_b, lo_a, size_a, n) -> None:
    lo_b, size_b, lo_a, size_a, n = (np.asarray(x).reshape(-1)
                                     for x in (lo_b, size_b, lo_a, size_a, n))

    def bad(cond: np.ndarray, what: str) -> None:
        if np.any(cond):
            lanes = np.nonzero(cond)[0][:4].tolist()
            record_violation(
                f"{op}: {what} (cap={cap}, lanes~{lanes}, "
                f"lo {lo_b[lanes[0]]}->{lo_a[lanes[0]]}, "
                f"size {size_b[lanes[0]]}->{size_a[lanes[0]]}, "
                f"n={n[lanes[0]]})")

    bad(n < 0, "negative count")
    bad((size_a < 0) | (size_a > cap), "size left [0, capacity]")
    bad((size_b < 0) | (size_b > cap), "size entered op outside [0, capacity]")
    if op in ("steal", "steal_exact"):
        bad(size_a != size_b - n, "size != size - n after steal")
        bad(lo_a != (lo_b + n) % cap, "steal cursor not bumped by n")
    elif op in ("push", "transfer"):
        bad(size_a != size_b + n, "size != size + n after push/splice")
        bad(lo_a != lo_b, "owner op moved the steal cursor")
    elif op in ("pop", "pop_bulk"):
        bad(size_a != size_b - n, "size != size - n after pop")
        bad(lo_a != lo_b, "owner op moved the steal cursor")


def _trace_check(op: str, cap: int, lo_b, size_b,
                 q_after: QueueState, n) -> None:
    """``lo_b`` / ``size_b`` are cursor values captured BEFORE the op ran
    (the op may have donated the input state, so the state itself must
    not be read afterwards — the lint pass's D1 rule)."""
    jax.debug.callback(functools.partial(_on_scalars, op, cap),
                       lo_b, size_b, q_after.lo, q_after.size,
                       jnp.asarray(n, jnp.int32))


def trace_check_superstep(sizes_before, sizes_after, *, capacity: int) -> None:
    """In-trace conservation check for one superstep level: the gathered
    size vectors (replicated per lane) must have equal sums and stay in
    ``[0, capacity]`` — inserted by ``master.superstep`` at trace time
    when the sanitizer is on (valid at BOTH hierarchical levels: each
    level's exchange conserves that level's effective sizes)."""

    def _cb(before, after):
        before = np.asarray(before)
        after = np.asarray(after)
        # Replicated vectors may arrive lane-stacked; compare flat sums
        # lane-by-lane along the last (gathered) axis.
        b = before.reshape(-1, before.shape[-1])
        a = after.reshape(-1, after.shape[-1])
        if np.any(b.sum(axis=-1) != a.sum(axis=-1)):
            record_violation(
                f"superstep: sum(sizes) not conserved "
                f"({b.sum(axis=-1).tolist()} -> {a.sum(axis=-1).tolist()})")
        if np.any((a < 0) | (a > capacity)):
            record_violation(
                f"superstep: sizes_after outside [0, {capacity}]")

    jax.debug.callback(_cb, sizes_before, sizes_after)


# ---------------------------------------------------------------------------
# The checked backend wrapper
# ---------------------------------------------------------------------------


class CheckedBulkOps(bulk_ops.BulkOps):
    """Delegating wrapper: same :class:`~repro.core.ops.BulkOps` surface,
    same results, every call validated (see module docstring).  Obtain
    via ``make_ops(..., check=True)`` or ``REPRO_CHECK=1``."""

    def __init__(self, inner: bulk_ops.BulkOps):
        super().__init__(inner.name, kernel_push=inner.kernel_push,
                         kernel_pop=inner.kernel_pop,
                         kernel_steal=inner.kernel_steal,
                         kernel_transfer=inner.kernel_transfer)
        self.inner = inner

    @property
    def resolved(self) -> str:
        return self.inner.resolved

    def __repr__(self) -> str:
        return f"CheckedBulkOps({self.inner!r})"

    def __getattr__(self, name: str):
        # Backend extras (e.g. RelaxedBulkOps.multiplicity_bound) pass
        # through; only called for attributes not found normally.
        return getattr(self.inner, name)

    # -- ops -----------------------------------------------------------------

    def push(self, q, batch, n, *, donate: bool = False):
        traced = _is_traced(q.size, q.lo, n)
        cap = _capacity(q)
        lo0, size0 = q.lo, q.size  # before the (possibly donating) op
        if not traced:
            size_b, lo_b = int(q.size), int(q.lo)
            live_b = _live_rows(q)
            bsz = jax.tree_util.tree_leaves(batch)[0].shape[0]
            n_req = int(n)
            rows_b = _batch_rows(batch, slice(None))
        q2, n_pushed = self.inner.push(q, batch, n, donate=donate)
        if traced:
            _trace_check("push", cap, lo0, size0, q2, n_pushed)
            return q2, n_pushed
        exp = max(min(n_req, cap - size_b), 0)
        got = int(n_pushed)
        if got != exp:
            record_violation(
                f"push: n_pushed={got}, expected clamp "
                f"min(n={n_req}, space={cap - size_b}) = {exp}", eager=True)
        if exp > bsz:
            record_violation(
                f"push: n={n_req} settled at {exp} > batch rows {bsz} — "
                f"garbage rows became live (caller contract: n <= B)",
                eager=True)
        self._owner_cursor(q2, lo_b, size_b + got, "push")
        live_a = _live_rows(q2)
        want = _concat(live_b, [r[:got] for r in rows_b])
        if not _rows_equal(live_a, want):
            record_violation(
                "push: live region != old live ++ batch[:n] "
                f"(lo={lo_b}, size {size_b}->{size_b + got})", eager=True)
        return q2, n_pushed

    def pop(self, q, *, donate: bool = False):
        traced = _is_traced(q.size, q.lo)
        cap = _capacity(q)
        lo0, size0 = q.lo, q.size
        if not traced:
            size_b, lo_b = int(q.size), int(q.lo)
            live_b = _live_rows(q)
        q2, item, valid = self.inner.pop(q, donate=donate)
        if traced:
            _trace_check("pop", cap, lo0, size0, q2,
                         jnp.asarray(valid, jnp.int32))
            return q2, item, valid
        exp_valid = size_b > 0
        if bool(valid) != exp_valid:
            record_violation(
                f"pop: valid={bool(valid)} on size={size_b}", eager=True)
        got = int(exp_valid)
        self._owner_cursor(q2, lo_b, size_b - got, "pop")
        if exp_valid:
            item_rows = [np.asarray(leaf)[None].copy()
                         for leaf in jax.tree_util.tree_leaves(item)]
            newest = [r[-1:] for r in live_b]
            if not _rows_equal(item_rows, newest):
                record_violation("pop: item != newest live row", eager=True)
        if not _rows_equal(_live_rows(q2), [r[:size_b - got] for r in live_b]):
            record_violation("pop: surviving live region changed",
                             eager=True)
        return q2, item, valid

    def pop_bulk(self, q, max_n: int, n, *, donate: bool = False):
        traced = _is_traced(q.size, q.lo, n)
        cap = _capacity(q)
        lo0, size0 = q.lo, q.size
        if not traced:
            size_b, lo_b, n_req = int(q.size), int(q.lo), int(n)
            live_b = _live_rows(q)
        q2, batch, n_popped = self.inner.pop_bulk(q, max_n, n, donate=donate)
        if traced:
            _trace_check("pop_bulk", cap, lo0, size0, q2, n_popped)
            return q2, batch, n_popped
        exp = max(min(n_req, size_b, max_n), 0)
        got = int(n_popped)
        if got != exp:
            record_violation(
                f"pop_bulk: n_popped={got}, expected "
                f"min(n={n_req}, size={size_b}, max_n={max_n}) = {exp}",
                eager=True)
        self._owner_cursor(q2, lo_b, size_b - got, "pop_bulk")
        self._block_out("pop_bulk", batch, got,
                        [r[size_b - got:size_b] for r in live_b])
        if not _rows_equal(_live_rows(q2), [r[:size_b - got] for r in live_b]):
            record_violation("pop_bulk: surviving live region changed",
                             eager=True)
        return q2, batch, n_popped

    def steal(self, q, proportion, *, max_steal: int,
              queue_limit: int = bulk_ops.DEFAULT_QUEUE_LIMIT,
              donate: bool = False):
        traced = _is_traced(q.size, q.lo, proportion)
        cap = _capacity(q)
        lo0, size0 = q.lo, q.size
        if not traced:
            size_b, lo_b = int(q.size), int(q.lo)
            live_b = _live_rows(q)
        q2, batch, n = self.inner.steal(q, proportion, max_steal=max_steal,
                                        queue_limit=queue_limit,
                                        donate=donate)
        if traced:
            _trace_check("steal", cap, lo0, size0, q2, n)
            return q2, batch, n
        exp = _mirror_steal_plan(size_b, proportion, queue_limit, max_steal)
        self._steal_checks("steal", q2, batch, int(n), exp, cap,
                           lo_b, size_b, live_b)
        return q2, batch, n

    def steal_exact(self, q, n, *, max_steal: int, donate: bool = False):
        traced = _is_traced(q.size, q.lo, n)
        cap = _capacity(q)
        lo0, size0 = q.lo, q.size
        if not traced:
            size_b, lo_b, n_req = int(q.size), int(q.lo), int(n)
            live_b = _live_rows(q)
        q2, batch, n_out = self.inner.steal_exact(q, n, max_steal=max_steal,
                                                  donate=donate)
        if traced:
            _trace_check("steal_exact", cap, lo0, size0, q2, n_out)
            return q2, batch, n_out
        exp = int(np.clip(n_req, 0, min(size_b, max_steal)))
        self._steal_checks("steal_exact", q2, batch, int(n_out), exp, cap,
                           lo_b, size_b, live_b)
        return q2, batch, n_out

    def window(self, q, *, max_steal: int, donate: bool = False):
        traced = _is_traced(q.size, q.lo)
        if not traced:  # snapshot before the call: q must not outlive it
            k = min(int(q.size), max_steal)
            live_b = [r[:k] for r in _live_rows(q)]
        window = self.inner.window(q, max_steal=max_steal, donate=donate)
        if not traced:
            if not _rows_equal(_batch_rows(window, slice(0, k)), live_b):
                record_violation(
                    "window: live prefix != queue's oldest rows",
                    eager=True)
        return window

    def transfer(self, q, gathered, src_row, n, *, max_steal: int,
                 donate: bool = False):
        traced = _is_traced(q.size, q.lo, src_row, n)
        cap = _capacity(q)
        lo0, size0 = q.lo, q.size
        if not traced:
            size_b, lo_b, n_req = int(q.size), int(q.lo), int(n)
            live_b = _live_rows(q)
            src = [np.asarray(leaf)[int(src_row)].copy()
                   for leaf in jax.tree_util.tree_leaves(gathered)]
        q2, n_out = self.inner.transfer(q, gathered, src_row, n,
                                        max_steal=max_steal, donate=donate)
        if traced:
            _trace_check("transfer", cap, lo0, size0, q2, n_out)
            return q2, n_out
        exp = max(min(n_req, cap - size_b, max_steal), 0)
        got = int(n_out)
        if got != exp:
            record_violation(
                f"transfer: n_spliced={got}, expected "
                f"min(n={n_req}, space={cap - size_b}, "
                f"max_steal={max_steal}) = {exp}", eager=True)
        self._owner_cursor(q2, lo_b, size_b + got, "transfer")
        if not _rows_equal(_live_rows(q2),
                           _concat(live_b, [r[:got] for r in src])):
            record_violation(
                "transfer: live region != old live ++ gathered[src, :n]",
                eager=True)
        return q2, n_out

    # -- shared eager assertions --------------------------------------------

    @staticmethod
    def _owner_cursor(q2, lo_b: int, size_exp: int, op: str) -> None:
        if int(q2.lo) != lo_b:
            record_violation(f"{op}: owner op moved the steal cursor "
                             f"({lo_b} -> {int(q2.lo)})", eager=True)
        if int(q2.size) != size_exp:
            record_violation(f"{op}: size {int(q2.size)} != {size_exp}",
                             eager=True)

    @staticmethod
    def _block_out(op: str, batch, n: int, want_rows) -> None:
        if not _rows_equal(_batch_rows(batch, slice(0, n)), want_rows):
            record_violation(f"{op}: batch[:n] != the detached live block",
                             eager=True)
        if not _zero_rows(batch, slice(n, None)):
            record_violation(f"{op}: rows >= n not zeroed (dead rows must "
                             f"be collective-safe)", eager=True)

    def _steal_checks(self, op, q2, batch, got, exp, cap, lo_b, size_b,
                      live_b) -> None:
        if got != exp:
            record_violation(f"{op}: n_stolen={got}, expected {exp}",
                             eager=True)
        if int(q2.lo) != (lo_b + got) % cap:
            record_violation(
                f"{op}: cursor lo {lo_b} -> {int(q2.lo)}, expected "
                f"(lo + {got}) % {cap} = {(lo_b + got) % cap}", eager=True)
        if int(q2.size) != size_b - got:
            record_violation(
                f"{op}: size {size_b} -> {int(q2.size)} != size - n",
                eager=True)
        self._block_out(op, batch, got, [r[:got] for r in live_b])
        if not _rows_equal(_live_rows(q2), [r[got:] for r in live_b]):
            record_violation(f"{op}: surviving live region changed",
                             eager=True)


# ---------------------------------------------------------------------------
# Executor-level checks (host side, after read-back)
# ---------------------------------------------------------------------------


def check_round_stats(stats, *, n_workers: int, capacity: int,
                      pod_size: Optional[int] = None,
                      context: str = "round") -> None:
    """Validate one round's :class:`~repro.core.master.RebalanceStats`
    after host read-back.  Flat mode: the gathered size vectors are
    replicated per lane — lane 0's row must conserve its sum and stay in
    bounds; counters must be non-negative.  Hierarchical mode: lanes > 0
    gathered sentinel sizes at the pod level, so only the counter-sign
    checks apply (the in-trace superstep check still covers each level's
    conservation)."""
    n_steals = np.asarray(stats.n_steals).reshape(-1)
    n_transferred = np.asarray(stats.n_transferred).reshape(-1)
    if np.any(n_steals < 0) or np.any(n_transferred < 0):
        record_violation(f"{context}: negative steal/transfer counters")
    if pod_size is None:
        before = np.asarray(stats.sizes_before)
        after = np.asarray(stats.sizes_after)
        b = before.reshape(-1, before.shape[-1])[0]
        a = after.reshape(-1, after.shape[-1])[0]
        if b.sum() != a.sum():
            record_violation(
                f"{context}: superstep lost items — sum(sizes) "
                f"{int(b.sum())} -> {int(a.sum())}")
        if np.any((a < 0) | (a > capacity)) or np.any(
                (b < 0) | (b > capacity)):
            record_violation(
                f"{context}: sizes outside [0, {capacity}]")


def _sorted_rows(a: np.ndarray) -> np.ndarray:
    flat = np.ascontiguousarray(a.reshape(a.shape[0], -1))
    if flat.shape[0] == 0:
        return flat
    return flat[np.lexsort(flat.T[::-1])]


def queues_fingerprint(queues: QueueState) -> List[np.ndarray]:
    """Order-independent multiset fingerprint of every live item across
    stacked lanes (leading axis = lanes): per buffer leaf, the live rows
    of all lanes concatenated and sorted lexicographically.  Two
    fingerprints are equal iff the live-item multisets are equal — the
    executor compares them across pure rebalancing rounds."""
    lanes = jax.tree_util.tree_leaves(queues.buf)[0].shape[0]
    los = np.asarray(queues.lo).reshape(-1)
    sizes = np.asarray(queues.size).reshape(-1)
    leaves = [np.asarray(leaf) for leaf in
              jax.tree_util.tree_leaves(queues.buf)]
    cap = leaves[0].shape[1]
    out: List[np.ndarray] = []
    for leaf in leaves:
        rows = []
        for w in range(lanes):
            idx = (int(los[w]) + np.arange(int(sizes[w]))) % cap
            rows.append(leaf[w][idx])
        out.append(_sorted_rows(np.concatenate(rows, axis=0) if rows
                                else leaf[:0]))
    return out


def check_conserved(before: List[np.ndarray], after: List[np.ndarray],
                    *, context: str) -> None:
    """Compare two :func:`queues_fingerprint` snapshots: a pure
    rebalancing round must preserve the live-item multiset exactly."""
    for i, (b, a) in enumerate(zip(before, after)):
        if b.shape != a.shape:
            record_violation(
                f"{context}: live-item count changed on leaf {i} "
                f"({b.shape[0]} -> {a.shape[0]} rows)")
        elif not np.array_equal(b, a):
            record_violation(
                f"{context}: live-item multiset changed on leaf {i} "
                f"(items duplicated or replaced)")
