"""Static invariant lint for the kernel/backend contract (AST only).

The BulkOps registry (PR 3) and the donation contract rest on three
conventions that nothing used to enforce mechanically.  This pass walks
the source tree **without executing anything** and checks:

``K1`` — kernel-package completeness
    Every package under ``src/repro/kernels/`` that ships a ``kernel.py``
    must (a) register a geometry predicate (a function whose name ends in
    ``_supported``) in that ``kernel.py``, (b) ship a jnp oracle
    (``ref.py`` defining at least one function), and (c) be exercised by
    a parity test (some file under ``tests/`` references
    ``kernels.<pkg>``).  The predicate is what lets dispatchers fall back
    to the oracle instead of tripping a kernel assert mid-trace.

``K2`` — donation mirror
    Every kernel that declares ``input_output_aliases`` writes its output
    in place, which is only sound when the caller's ring buffer is
    actually donated.  For each aliasing kernel package, the BulkOps ops
    it serves must appear in the ``_donating`` jit namespace with
    ``donate_argnums`` set, and the corresponding ``BulkOps`` method must
    expose a ``donate`` keyword.

``D1`` — use-after-donate
    A value passed as the queue-state argument of a ``donate=True`` call
    must not be read again in the same scope before being rebound: after
    donation the old buffer may have been overwritten in place.  The scan
    is linear per function scope, models execution order inside a
    statement (values load before targets bind, so the idiomatic
    ``q, out = ops.push(q, ..., donate=True)`` is clean), and tracks
    dotted names (``self.state``).

``U1`` — ``use_kernel``-era patterns
    The pre-BulkOps dialect (``use_kernel=`` keywords, ``*_inplace``
    function names) was removed at PR 3; any syntactic reappearance is
    flagged.  Docstrings and comments are naturally exempt (AST).

CLI::

    python -m repro.analysis.lint [paths...]   # default: src benchmarks examples

Exit status 1 iff any finding.  Wired into CI's ``analysis`` lane.
"""

from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, List, Optional

__all__ = ["Finding", "lint_paths", "lint_file", "main"]

REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_PATHS = ("src", "benchmarks", "examples")

# K2: which BulkOps ops each in-place (aliasing) kernel package serves.
# Only packages whose kernel.py declares input_output_aliases are held
# to the mirror; this table says which methods must then be donatable.
ALIASING_OPS = {
    "queue_push": ("push",),
    "queue_transfer": ("transfer",),
    "queue_steal": ("steal", "steal_exact"),
    "queue_pop": ("pop", "pop_bulk"),
}


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _parse(path: Path) -> Optional[ast.Module]:
    try:
        return ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return None


def _dotted(node: ast.AST) -> Optional[str]:
    """``a`` / ``a.b.c`` -> dotted name string, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _rel(path: Path) -> str:
    try:
        return str(path.relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


# ---------------------------------------------------------------------------
# K1: kernel-package completeness
# ---------------------------------------------------------------------------


def _check_kernel_packages(root: Path, tests_dir: Path) -> List[Finding]:
    kernels = root / "src" / "repro" / "kernels"
    if not kernels.is_dir():
        return []
    test_text = "".join(p.read_text() for p in sorted(tests_dir.glob("**/*.py"))) \
        if tests_dir.is_dir() else ""
    out: List[Finding] = []
    for pkg in sorted(p for p in kernels.iterdir() if p.is_dir()):
        kernel_py = pkg / "kernel.py"
        if not kernel_py.is_file():
            continue
        tree = _parse(kernel_py)
        if tree is None:
            out.append(Finding("K1", _rel(kernel_py), 1, "kernel.py does not parse"))
            continue
        preds = [n for n in ast.walk(tree)
                 if isinstance(n, ast.FunctionDef) and n.name.endswith("_supported")]
        if not preds:
            out.append(Finding(
                "K1", _rel(kernel_py), 1,
                f"kernel package '{pkg.name}' registers no geometry predicate "
                f"(no function ending '_supported' in kernel.py) — dispatchers "
                f"cannot route around its asserts"))
        ref_py = pkg / "ref.py"
        ref_tree = _parse(ref_py) if ref_py.is_file() else None
        has_ref = ref_tree is not None and any(
            isinstance(n, ast.FunctionDef) for n in ast.walk(ref_tree))
        if not has_ref:
            out.append(Finding(
                "K1", _rel(pkg / "ref.py"), 1,
                f"kernel package '{pkg.name}' ships no jnp oracle "
                f"(ref.py missing or defines no function)"))
        if f"kernels.{pkg.name}" not in test_text:
            out.append(Finding(
                "K1", _rel(pkg), 1,
                f"kernel package '{pkg.name}' has no parity test "
                f"(nothing under tests/ references kernels.{pkg.name})"))
    return out


# ---------------------------------------------------------------------------
# K2: input_output_aliases <-> donate mirror
# ---------------------------------------------------------------------------


def _kernel_aliases(kernel_py: Path) -> bool:
    tree = _parse(kernel_py)
    if tree is None:
        return False
    for node in ast.walk(tree):
        if isinstance(node, ast.keyword) and node.arg == "input_output_aliases":
            return True
    return False


def _donating_namespace_ops(ops_py: Path) -> dict:
    """Map op name -> bool(donate_argnums present) from ``_donating``."""
    tree = _parse(ops_py)
    found: dict = {}
    if tree is None:
        return found
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == "_donating":
            for call in ast.walk(node):
                if not (isinstance(call, ast.Call)
                        and isinstance(call.func, ast.Attribute)):
                    continue
                # the SimpleNamespace(...) call carries op=jax.jit(...) kwargs
                if call.func.attr != "SimpleNamespace":
                    continue
                for kw in call.keywords:
                    if kw.arg is None:
                        continue
                    donated = any(
                        isinstance(inner, ast.keyword)
                        and inner.arg == "donate_argnums"
                        for inner in ast.walk(kw.value))
                    found[kw.arg] = donated
    return found


def _bulkops_donate_kwargs(ops_py: Path) -> set:
    """Names of BulkOps methods exposing a ``donate`` keyword."""
    tree = _parse(ops_py)
    out: set = set()
    if tree is None:
        return out
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "BulkOps":
            for fn in node.body:
                if isinstance(fn, ast.FunctionDef) and any(
                        a.arg == "donate" for a in fn.args.kwonlyargs + fn.args.args):
                    out.add(fn.name)
    return out


def _check_donation_mirror(root: Path) -> List[Finding]:
    kernels = root / "src" / "repro" / "kernels"
    ops_py = root / "src" / "repro" / "core" / "ops.py"
    if not (kernels.is_dir() and ops_py.is_file()):
        return []
    namespace = _donating_namespace_ops(ops_py)
    donate_kwargs = _bulkops_donate_kwargs(ops_py)
    out: List[Finding] = []
    for pkg in sorted(p for p in kernels.iterdir() if p.is_dir()):
        kernel_py = pkg / "kernel.py"
        if not (kernel_py.is_file() and _kernel_aliases(kernel_py)):
            continue
        served = ALIASING_OPS.get(pkg.name)
        if served is None:
            out.append(Finding(
                "K2", _rel(kernel_py), 1,
                f"kernel package '{pkg.name}' declares input_output_aliases "
                f"but is not in the lint ALIASING_OPS table — add its served "
                f"BulkOps ops so the donation mirror is checked"))
            continue
        for op in served:
            if not namespace.get(op, False):
                out.append(Finding(
                    "K2", _rel(ops_py), 1,
                    f"kernel '{pkg.name}' aliases its ring in place but "
                    f"_donating has no donate_argnums-jitted '{op}' — the "
                    f"in-place write is unsound without donation"))
            if op not in donate_kwargs:
                out.append(Finding(
                    "K2", _rel(ops_py), 1,
                    f"kernel '{pkg.name}' aliases its ring in place but "
                    f"BulkOps.{op} exposes no donate= keyword"))
    return out


# ---------------------------------------------------------------------------
# D1: use-after-donate
# ---------------------------------------------------------------------------


class _ScopeScanner:
    """Linear event scan of one function scope (or module top level).

    Events, in execution order: ``load(name)``, ``donate(name)``,
    ``bind(name)``.  Inside a statement, value expressions emit their
    loads (and donates) before assignment targets bind — so the idiom
    ``q, out = ops.push(q, batch, n, donate=True)`` donates then
    immediately rebinds and stays clean, while a later bare read of a
    still-donated name is flagged.
    """

    def __init__(self, path: str):
        self.path = path
        self.donated: dict = {}  # dotted name -> donate lineno
        self.findings: List[Finding] = []

    # -- events --

    def load(self, name: str, line: int) -> None:
        for don, dline in self.donated.items():
            if name == don or name.startswith(don + "."):
                self.findings.append(Finding(
                    "D1", self.path, line,
                    f"'{name}' is read after being donated at line {dline} "
                    f"(donate=True aliases the buffer in place; rebind the "
                    f"name from the op's return value first)"))

    def donate(self, name: str, line: int) -> None:
        self.donated[name] = line

    def bind(self, name: str) -> None:
        self.donated.pop(name, None)

    # -- expression walk (loads + donates, execution order) --

    def expr(self, node: ast.AST) -> None:
        if node is None:
            return
        dotted = _dotted(node)
        if dotted is not None and isinstance(getattr(node, "ctx", None), ast.Load):
            self.load(dotted, node.lineno)
            return  # a.b.c counted once, not per attribute level
        if isinstance(node, ast.Call):
            self.expr(node.func)
            for a in node.args:
                self.expr(a)
            for kw in node.keywords:
                self.expr(kw.value)
            donate_kw = next(
                (kw for kw in node.keywords if kw.arg == "donate"), None)
            if donate_kw is not None and not (
                    isinstance(donate_kw.value, ast.Constant)
                    and donate_kw.value.value is False) and node.args:
                target = _dotted(node.args[0])
                if target is not None:
                    self.donate(target, node.lineno)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                             ast.ClassDef)):
            return  # separate scope
        for child in ast.iter_child_nodes(node):
            self.expr(child)

    # -- statement walk --

    def bind_target(self, node: ast.AST) -> None:
        if isinstance(node, (ast.Tuple, ast.List)):
            for elt in node.elts:
                self.bind_target(elt)
            return
        if isinstance(node, ast.Starred):
            self.bind_target(node.value)
            return
        dotted = _dotted(node)
        if dotted is not None:
            self.bind(dotted)
        else:  # subscript etc: value part is a load
            self.expr(node)

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # nested scope scanned separately
        if isinstance(node, ast.Assign):
            self.expr(node.value)
            for t in node.targets:
                self.bind_target(t)
            return
        if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            self.expr(node.value)
            self.bind_target(node.target)
            return
        if isinstance(node, (ast.For, ast.AsyncFor)):
            self.expr(node.iter)
            self.bind_target(node.target)
            for s in node.body + node.orelse:
                self.stmt(s)
            return
        if isinstance(node, (ast.If, ast.While)):
            self.expr(node.test)
            for s in node.body + node.orelse:
                self.stmt(s)
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                self.expr(item.context_expr)
                if item.optional_vars is not None:
                    self.bind_target(item.optional_vars)
            for s in node.body:
                self.stmt(s)
            return
        if isinstance(node, ast.Try):
            for s in node.body + node.orelse + node.finalbody:
                self.stmt(s)
            for h in node.handlers:
                for s in h.body:
                    self.stmt(s)
            return
        # Return / Expr / Assert / Raise / Delete / ...: walk expressions
        for child in ast.iter_child_nodes(node):
            self.expr(child)


def _check_use_after_donate(path: Path, tree: ast.Module) -> List[Finding]:
    findings: List[Finding] = []
    scopes: List[List[ast.stmt]] = [list(tree.body)]
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scopes.append(list(node.body))
    for body in scopes:
        sc = _ScopeScanner(_rel(path))
        for stmt in body:
            sc.stmt(stmt)
        findings.extend(sc.findings)
    return findings


# ---------------------------------------------------------------------------
# U1: use_kernel-era patterns
# ---------------------------------------------------------------------------


def _check_use_kernel_era(path: Path, tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    rel = _rel(path)
    for node in ast.walk(tree):
        if isinstance(node, ast.keyword) and node.arg == "use_kernel":
            out.append(Finding(
                "U1", rel, node.value.lineno,
                "use_kernel= keyword — the flag dialect was removed at PR 3; "
                "construct a backend with make_ops(...) instead"))
        if isinstance(node, ast.FunctionDef) and node.name.endswith("_inplace"):
            out.append(Finding(
                "U1", rel, node.lineno,
                f"'{node.name}' — *_inplace variants were removed at PR 3; "
                f"use the backend's donate=True call shape"))
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                if alias.name.endswith("_inplace"):
                    out.append(Finding(
                        "U1", rel, node.lineno,
                        f"import of '{alias.name}' — *_inplace variants were "
                        f"removed at PR 3"))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def lint_file(path: Path) -> List[Finding]:
    """Per-file rules only (D1, U1)."""
    tree = _parse(path)
    if tree is None:
        return [Finding("E0", _rel(path), 1, "file does not parse")]
    return _check_use_after_donate(path, tree) + _check_use_kernel_era(path, tree)


def lint_paths(paths: Iterable[Path], *, root: Path = REPO_ROOT) -> List[Finding]:
    findings: List[Finding] = []
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.glob("**/*.py")))
        elif p.is_file():
            files.append(p)
    for f in files:
        findings.extend(lint_file(f))
    findings.extend(_check_kernel_packages(root, root / "tests"))
    findings.extend(_check_donation_mirror(root))
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    paths = [Path(a) for a in argv] if argv else [
        REPO_ROOT / d for d in DEFAULT_PATHS]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    n_files = sum(len(list(Path(p).glob('**/*.py'))) if Path(p).is_dir() else 1
                  for p in paths)
    if findings:
        print(f"lint: {len(findings)} finding(s) across {n_files} file(s)")
        return 1
    print(f"lint: clean ({n_files} file(s))")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
